//! `weights.bin` loader (format documented in `python/compile/export.py`).
//!
//! [`ModelWeights::load`] sniffs the magic and dispatches: `RMSW` is the
//! legacy float-weight container (parse + quantize + sort at load — the
//! oracle path), `RMSA` is the packed artifact (`super::artifact`) whose
//! quantized sections are aliased zero-copy from an `mmap`.

use std::io::Read;
use std::path::Path;

use crate::bail;
use crate::err;
use crate::gemm::{PackedWeights, SortedWeights};
use crate::quant::{Mat, Scheme};
use crate::util::error::{Context, Result};

/// One folded layer: float weights + quantization metadata + packed codes.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub name: String,
    pub kind: String, // "conv" | "linear"
    pub rows: usize,
    pub cols: usize,
    // conv geometry (zeros for linear)
    pub out_ch: usize,
    pub in_ch: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
    pub groups: usize,
    pub a_alpha: f32,
    pub scheme: Vec<Scheme>,
    pub alpha: Vec<f32>,
    pub bias: Vec<f32>,
    /// Float folded weights, (rows, cols) row-major. `None` on the
    /// artifact load path — the packed `.rmsa` container stores only the
    /// quantized planes, so float-weight consumers (the assignment
    /// engine, the RMSW writer) must load the legacy format.
    pub w: Option<Mat>,
    /// Integer codes for the GEMM cores (model row order).
    pub packed: PackedWeights,
    /// Class-sorted kernel layout: `packed` permuted once at load so each
    /// scheme class is one contiguous block, plus the permutation and its
    /// inverse for output scatter. This is what the compiled-plan
    /// executor's mixed GEMM actually runs on.
    pub sorted: SortedWeights,
}

/// All layers of one model, in manifest order.
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub layers: Vec<LayerWeights>,
}

/// `Read::read` until `buf` is full or EOF; returns the bytes read
/// (plain `read_exact` would error on sub-4-byte files before the
/// format dispatch gets to reject them with a real message).
fn read_up_to(f: &mut std::fs::File, buf: &mut [u8]) -> Result<usize> {
    let mut n = 0;
    while n < buf.len() {
        let k = f.read(&mut buf[n..])?;
        if k == 0 {
            break;
        }
        n += k;
    }
    Ok(n)
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("weights.bin truncated at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(4 * n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

impl ModelWeights {
    /// Load either weights format, dispatching on the magic: `RMSA`
    /// artifacts go through the zero-copy [`super::artifact`] loader
    /// (discarding the embedded manifest — use [`super::artifact::load`]
    /// to get both), anything else through the legacy `RMSW` parser.
    pub fn load(path: &Path) -> Result<ModelWeights> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut magic = [0u8; 4];
        let got = read_up_to(&mut f, &mut magic)?;
        if got == 4 && magic == *super::artifact::MAGIC {
            drop(f);
            let (_, weights) = super::artifact::load(path)?;
            return Ok(weights);
        }
        let mut buf = magic[..got].to_vec();
        f.read_to_end(&mut buf)?;
        Self::parse(&buf).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(buf: &[u8]) -> Result<ModelWeights> {
        let mut c = Cursor { b: buf, i: 0 };
        if c.take(4)? != b"RMSW" {
            bail!("bad magic (want RMSW)");
        }
        let version = c.u32()?;
        if version != 1 {
            bail!("unsupported weights.bin version {version}");
        }
        let n_layers = c.u32()? as usize;
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let name_len = c.u32()? as usize;
            let name = String::from_utf8(c.take(name_len)?.to_vec())?;
            let kind_code = c.u8()?;
            let _relu = c.u8()?;
            let rows = c.u32()? as usize;
            let cols = c.u32()? as usize;
            let out_ch = c.u32()? as usize;
            let in_ch = c.u32()? as usize;
            let kh = c.u32()? as usize;
            let kw = c.u32()? as usize;
            let stride = c.u32()? as usize;
            let pad = c.u32()? as usize;
            let groups = c.u32()? as usize;
            let a_alpha = c.f32()?;
            let scheme_raw = c.take(rows)?;
            let scheme: Vec<Scheme> = scheme_raw
                .iter()
                .map(|&b| Scheme::from_code(b).ok_or_else(|| err!("bad scheme {b}")))
                .collect::<Result<_>>()?;
            let alpha = c.f32_vec(rows)?;
            let bias = c.f32_vec(rows)?;
            let w = Mat::from_vec(rows, cols, c.f32_vec(rows * cols)?);
            let packed = PackedWeights::quantize(&w, &scheme, &alpha);
            let sorted = SortedWeights::from_packed(&packed);
            layers.push(LayerWeights {
                name,
                kind: if kind_code == 0 { "conv" } else { "linear" }.to_string(),
                rows,
                cols,
                out_ch,
                in_ch,
                kh,
                kw,
                stride,
                pad,
                groups,
                a_alpha,
                scheme,
                alpha,
                bias,
                w: Some(w),
                packed,
                sorted,
            });
        }
        if c.i != buf.len() {
            bail!("{} trailing bytes in weights.bin", buf.len() - c.i);
        }
        Ok(ModelWeights { layers })
    }

    pub fn layer(&self, name: &str) -> Result<&LayerWeights> {
        self.layers
            .iter()
            .find(|l| l.name == name)
            .ok_or_else(|| err!("layer {name:?} not in weights.bin"))
    }

    /// Index of a layer in [`ModelWeights::layers`] — the plan compiler
    /// resolves names to indices once so the runner never string-matches.
    pub fn layer_index(&self, name: &str) -> Result<usize> {
        self.layers
            .iter()
            .position(|l| l.name == name)
            .ok_or_else(|| err!("layer {name:?} not in weights.bin"))
    }

    /// Total quantized model size in bytes (the compression headline).
    pub fn quantized_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.packed.storage_bits() / 8)
            .sum()
    }

    /// Float32 model size in bytes.
    pub fn float_bytes(&self) -> usize {
        self.layers.iter().map(|l| 4 * l.rows * l.cols).sum()
    }

    /// Serialize back to the legacy `RMSW` v1 container (the inverse of
    /// [`ModelWeights::parse`]). Requires float weights, so it only works
    /// on legacy-loaded or crate-built models — the bench harness and the
    /// pack round-trip tests use it to materialize a `weights.bin` for
    /// models that were never exported from Python.
    pub fn to_weights_bin(&self) -> Result<Vec<u8>> {
        let mut v = Vec::new();
        v.extend_from_slice(b"RMSW");
        v.extend_from_slice(&1u32.to_le_bytes());
        v.extend_from_slice(&(self.layers.len() as u32).to_le_bytes());
        for l in &self.layers {
            let w = l
                .w
                .as_ref()
                .ok_or_else(|| err!("layer {:?} holds no float weights (artifact-loaded?)", l.name))?;
            v.extend_from_slice(&(l.name.len() as u32).to_le_bytes());
            v.extend_from_slice(l.name.as_bytes());
            v.push(if l.kind == "conv" { 0 } else { 1 });
            v.push(0); // relu byte (unused by the parser)
            for x in [l.rows, l.cols, l.out_ch, l.in_ch, l.kh, l.kw, l.stride, l.pad, l.groups] {
                v.extend_from_slice(&(x as u32).to_le_bytes());
            }
            v.extend_from_slice(&l.a_alpha.to_le_bytes());
            v.extend(l.scheme.iter().map(|&s| s as u8));
            for &a in &l.alpha {
                v.extend_from_slice(&a.to_le_bytes());
            }
            for &b in &l.bias {
                v.extend_from_slice(&b.to_le_bytes());
            }
            for &x in &w.data {
                v.extend_from_slice(&x.to_le_bytes());
            }
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-build a tiny weights.bin in memory.
    fn tiny_bin() -> Vec<u8> {
        let mut v = Vec::new();
        v.extend_from_slice(b"RMSW");
        v.extend_from_slice(&1u32.to_le_bytes());
        v.extend_from_slice(&1u32.to_le_bytes()); // one layer
        let name = b"fc";
        v.extend_from_slice(&(name.len() as u32).to_le_bytes());
        v.extend_from_slice(name);
        v.push(1); // linear
        v.push(0);
        let (rows, cols) = (2u32, 3u32);
        v.extend_from_slice(&rows.to_le_bytes());
        v.extend_from_slice(&cols.to_le_bytes());
        for x in [rows, cols, 1, 1, 0, 0, 1] {
            v.extend_from_slice(&x.to_le_bytes());
        }
        v.extend_from_slice(&1.0f32.to_le_bytes()); // a_alpha
        v.extend_from_slice(&[1u8, 0u8]); // schemes: Fixed4, PoT4
        for a in [1.0f32, 1.0] {
            v.extend_from_slice(&a.to_le_bytes());
        }
        for b in [0.1f32, -0.2] {
            v.extend_from_slice(&b.to_le_bytes());
        }
        for w in [0.5f32, -0.25, 1.0, 0.7, 0.0, -1.0] {
            v.extend_from_slice(&w.to_le_bytes());
        }
        v
    }

    #[test]
    fn parses_tiny_model() {
        let m = ModelWeights::parse(&tiny_bin()).unwrap();
        assert_eq!(m.layers.len(), 1);
        let l = &m.layers[0];
        assert_eq!(l.name, "fc");
        assert_eq!(l.kind, "linear");
        assert_eq!(l.scheme, vec![Scheme::FixedW4A4, Scheme::PotW4A4]);
        assert_eq!(l.w.as_ref().unwrap().at(0, 0), 0.5);
        assert_eq!(l.bias, vec![0.1, -0.2]);
        // the class-sorted layout is built at load: PoT row 1 sorts ahead
        // of Fixed row 0
        assert_eq!(l.sorted.perm, vec![1, 0]);
        assert_eq!(l.sorted.inv, vec![1, 0]);
        assert_eq!(l.sorted.partition().total(), 2);
        assert!(m.layer("fc").is_ok());
        assert!(m.layer("missing").is_err());
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        let mut b = tiny_bin();
        b[0] = b'X';
        assert!(ModelWeights::parse(&b).is_err());
        let b = tiny_bin();
        assert!(ModelWeights::parse(&b[..b.len() - 3]).is_err());
        let mut b = tiny_bin();
        b.push(0);
        assert!(ModelWeights::parse(&b).is_err()); // trailing bytes
    }

    #[test]
    fn weights_bin_writer_roundtrips() {
        let bin = tiny_bin();
        let m = ModelWeights::parse(&bin).unwrap();
        assert_eq!(m.to_weights_bin().unwrap(), bin);
    }

    #[test]
    fn size_accounting() {
        let m = ModelWeights::parse(&tiny_bin()).unwrap();
        assert_eq!(m.float_bytes(), 4 * 6);
        assert_eq!(m.quantized_bytes(), (4 * 3 + 4 * 3) / 8);
    }
}
