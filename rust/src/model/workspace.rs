//! Reusable inference workspace: every buffer `infer` touches, owned and
//! preallocated once per executor.
//!
//! A [`Workspace`] is the mutable half of the compile-then-run split (the
//! immutable half is the [`super::plan::Plan`]): slot buffers for every
//! program value, the im2col patch matrix, the quantized-activation code
//! buffer, the GEMM/Gap staging matrix, the per-lane GEMM row scratch,
//! and the logits output. All of them are sized from the plan's
//! high-water [`super::plan::Footprint`] at construction — computed
//! strictly after the optimizer pass pipeline, so slots the passes made
//! codes-only or dead get no f32 bytes, and streamed (implicit or
//! depthwise) convs budget per-lane panels instead of patch matrices —
//! so a
//! steady-state `infer` call at or below the plan's batch capacity never
//! allocates a buffer — everything is `resize`d (a length change inside
//! existing capacity) and overwritten in place; sequentially that means
//! zero heap allocation outright, while parallel dispatch still boxes
//! O(threads) pool jobs per GEMM. Batches beyond capacity run
//! correctly: the buffers grow once and the new capacity becomes the
//! steady state.
//!
//! One workspace per concurrent inference stream: the serving coordinator
//! gives every worker its own, next to the shared `Arc<Plan>` and
//! `Arc<ModelWeights>`.

use crate::gemm::{GemmScratch, PackedActs};
use crate::quant::Mat;

use super::plan::Plan;

/// Preallocated mutable state for one inference stream (see module docs).
pub struct Workspace {
    /// One flat f32 buffer per plan slot.
    pub(crate) slots: Vec<Vec<f32>>,
    /// One flat u8 code buffer per plan slot — the integer-resident
    /// inter-layer currency. Zero-capacity for slots the plan's domain
    /// inference keeps in f32 (and vice versa: a codes-only slot's f32
    /// buffer stays empty).
    pub(crate) code_slots: Vec<Vec<u8>>,
    /// im2col patch matrix — the explicit-path fallback (grouped convs;
    /// empty-capacity when every conv in the plan runs implicitly).
    pub(crate) patches: Mat,
    /// Quantized activation codes, reused by the explicit-path convs
    /// and the linear ops (implicit convs stream per-lane panels).
    pub(crate) acts: PackedActs,
    /// GEMM output / Gap staging matrix.
    pub(crate) stage: Mat,
    /// Per-lane GEMM micro-kernel scratch (a `MAX_MICRO_ROWS x batch`
    /// f32 output block + i32 accumulator block + u8 code block per
    /// lane, plus the implicit-GEMM activation panel) — sized at the
    /// widest block height any per-layer tuned knob can install, so
    /// retuning never regrows a lane.
    pub(crate) scratch: GemmScratch,
    /// Logits returned by `infer` (borrowed out, overwritten per call).
    pub(crate) logits: Mat,
}

fn mat_with_capacity(cap: usize) -> Mat {
    Mat { rows: 0, cols: 0, data: Vec::with_capacity(cap) }
}

impl Workspace {
    /// Preallocate for `plan` with `lanes` GEMM scratch lanes (see
    /// [`crate::gemm::MixedGemm::lanes`]).
    pub fn new(plan: &Plan, lanes: usize) -> Workspace {
        let fp = plan.footprint(lanes);
        Workspace {
            slots: fp.slot_elems.iter().map(|&n| Vec::with_capacity(n)).collect(),
            code_slots: fp
                .code_slot_elems
                .iter()
                .map(|&n| Vec::with_capacity(n))
                .collect(),
            patches: mat_with_capacity(fp.patch_elems),
            acts: PackedActs::with_capacity(fp.acts_elems),
            stage: mat_with_capacity(fp.gemm_out_elems),
            scratch: GemmScratch::with_capacity(fp.lanes, fp.lane_elems, fp.panel_elems),
            logits: mat_with_capacity(fp.logits_elems),
        }
    }

    /// Data pointers of every owned buffer. Steady-state reuse tests pin
    /// these across `infer` calls: if no buffer reallocates, the pointers
    /// are identical call over call.
    pub fn buffer_ptrs(&self) -> Vec<usize> {
        let mut p: Vec<usize> = self.slots.iter().map(|s| s.as_ptr() as usize).collect();
        p.extend(self.code_slots.iter().map(|s| s.as_ptr() as usize));
        p.push(self.patches.data.as_ptr() as usize);
        p.push(self.acts.codes.as_ptr() as usize);
        p.push(self.stage.data.as_ptr() as usize);
        p.push(self.logits.data.as_ptr() as usize);
        p.extend(self.scratch.buffer_ptrs());
        p
    }

    /// Bytes currently reserved across all buffers.
    pub fn allocated_bytes(&self) -> usize {
        let slots: usize = self.slots.iter().map(|s| 4 * s.capacity()).sum();
        let code_slots: usize = self.code_slots.iter().map(|s| s.capacity()).sum();
        slots
            + code_slots
            + 4 * self.patches.data.capacity()
            + self.acts.codes.capacity()
            + 4 * self.stage.data.capacity()
            + 4 * self.logits.data.capacity()
            + self.scratch.allocated_bytes()
    }

    /// The current f32 contents of a plan slot (differential tests pin
    /// integer-resident activations against these).
    pub fn slot_f32(&self, id: usize) -> &[f32] {
        &self.slots[id]
    }

    /// The current u8 activation codes of an integer-resident plan slot.
    pub fn slot_codes(&self, id: usize) -> &[u8] {
        &self.code_slots[id]
    }
}
