//! The plan compiler's intermediate representation: `manifest.program`
//! lowered to slot-indexed ops, before optimization.
//!
//! [`Ir::lower`] does name resolution and shape checking **only**: every
//! buffer name becomes a dense [`SlotId`], every op gets its geometry
//! (im2col dims, group split, GEMM task schedule) precomputed and
//! validated, and nothing else. All dataflow decisions — output domains,
//! conv strategy, epilogue fusion, depthwise specialization, dead-slot
//! elimination — are rewrites applied afterwards by the pass pipeline in
//! [`super::passes`]. The lowered IR is therefore the most conservative
//! legal plan: every edge f32, every conv on the staged explicit path.
//!
//! The IR deliberately reuses the executor's op type ([`PlanOp`]): a
//! pass rewrites exactly the struct the runner will walk, so there is no
//! separate legalization step between "optimized IR" and "plan" — the
//! builder seals the IR into a [`super::plan::Plan`] once the pipeline
//! finishes.

use std::collections::HashMap;

use crate::ensure;
use crate::err;
use crate::gemm::{chunk_tasks, ParallelConfig, RowPartition};
use crate::util::error::Result;

use super::im2col::out_dim;
use super::manifest::{Manifest, OpMeta};
use super::plan::{define, PlanOp, SlotId, SlotKind, SlotSpec};
use super::weights::ModelWeights;

/// One layer's effective blocking knobs, resolved by the plan builder
/// (per-layer autotuner winners merged with the caller's config under
/// the explicit-wins contract) before lowering. The lowering bakes
/// `micro_rows`/`tile_cols` into the layer's [`PlanOp`] and chunks its
/// schedule at `chunk_rows`; the `implicit`/`depthwise` passes size the
/// layer's streamed panels from `panel_bytes`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct LayerKnobs {
    pub(crate) micro_rows: usize,
    pub(crate) tile_cols: usize,
    pub(crate) chunk_rows: usize,
    pub(crate) panel_bytes: usize,
}

/// The mutable program the pass pipeline rewrites (see module docs).
/// Slots and ops are exactly the plan's; the rest is the compile context
/// passes need to make decisions (weights for scales and schemes, the
/// capacity and chunking the schedules were sized for).
pub(crate) struct Ir<'w> {
    pub(crate) weights: &'w ModelWeights,
    pub(crate) model: String,
    pub(crate) capacity: usize,
    pub(crate) chunk_rows: usize,
    /// Implicit-GEMM panel budget in bytes (autotuned or the fixed
    /// default) — the global fallback; passes prefer the per-layer
    /// value in [`Ir::layer_knobs`].
    pub(crate) panel_bytes: usize,
    /// Per-weights-layer effective blocking knobs (see [`LayerKnobs`]),
    /// `ModelWeights::layers` order.
    pub(crate) layer_knobs: Vec<LayerKnobs>,
    pub(crate) act_bits: u32,
    pub(crate) input_slot: SlotId,
    pub(crate) input_chw: (usize, usize, usize),
    pub(crate) logits_slot: SlotId,
    pub(crate) logits_cols: usize,
    pub(crate) slots: Vec<SlotSpec>,
    pub(crate) ops: Vec<PlanOp>,
    pub(crate) layer_parts: Vec<RowPartition>,
}

impl<'w> Ir<'w> {
    /// Lower `manifest.program` against `weights`: resolve names to slot
    /// ids, precompute and shape-check per-op geometry, chunk the GEMM
    /// task schedules. `capacity` (batch images), `cfg` (task
    /// granularity), and `panel_bytes` (the possibly-autotuned panel
    /// budget) are recorded for the passes that size panels and
    /// schedules; `layer_knobs` carries the per-layer tuned blocking
    /// (one entry per weights layer) baked into the layer ops and
    /// schedules.
    pub(crate) fn lower(
        manifest: &Manifest,
        weights: &'w ModelWeights,
        capacity: usize,
        cfg: &ParallelConfig,
        panel_bytes: usize,
        layer_knobs: Vec<LayerKnobs>,
    ) -> Result<Ir<'w>> {
        ensure!(
            layer_knobs.len() == weights.layers.len(),
            "layer knobs for {} layers, weights have {}",
            layer_knobs.len(),
            weights.layers.len()
        );
        ensure!(
            manifest.input_shape.len() == 4,
            "manifest input_shape must be NCHW, got {:?}",
            manifest.input_shape
        );
        let capacity = capacity.max(1);
        let chunk_rows = cfg.min_rows_per_task.max(1);
        let input_chw = (
            manifest.input_shape[1],
            manifest.input_shape[2],
            manifest.input_shape[3],
        );

        let layer_parts: Vec<RowPartition> = weights
            .layers
            .iter()
            .map(|l| RowPartition::from_schemes(&l.scheme))
            .collect();

        let mut slots: Vec<SlotSpec> = Vec::new();
        let mut index: HashMap<String, SlotId> = HashMap::new();

        // The program input is pre-seeded under the fixed name "in0",
        // mirroring the interpreter's calling convention.
        let input_kind = SlotKind::T4 { c: input_chw.0, h: input_chw.1, w: input_chw.2 };
        let input_slot = 0;
        slots.push(SlotSpec {
            name: "in0".to_string(),
            kind: input_kind,
            per_image: input_kind.per_image(),
            // `infer` seeds the input as floats — the first conv always
            // quantizes (the f32 entry edge of the pipeline)
            holds_f32: true,
            holds_codes: false,
            code_nhwc: false,
        });
        index.insert("in0".to_string(), input_slot);

        // Every id in `index` has been written (define records the shape
        // of the latest write in slots[id].kind), so lookup is the only
        // failure mode.
        let read = |slots: &[SlotSpec],
                    index: &HashMap<String, SlotId>,
                    name: &str|
         -> Result<(SlotId, SlotKind)> {
            let id = *index
                .get(name)
                .ok_or_else(|| err!("missing buffer {name}"))?;
            Ok((id, slots[id].kind))
        };

        let mut ops = Vec::with_capacity(manifest.program.len());

        for op in &manifest.program {
            match op {
                OpMeta::Conv { layer, input, out, relu } => {
                    manifest.layer(layer)?;
                    let li = weights.layer_index(layer)?;
                    let lw = &weights.layers[li];
                    let (in_id, kind) = read(&slots, &index, input)?;
                    let SlotKind::T4 { c, h, w } = kind else {
                        return Err(err!("conv {layer}: input {input} is not a 4-D buffer"));
                    };
                    let k = lw.kh;
                    let stride = lw.stride;
                    let pad = lw.pad;
                    let groups = lw.groups.max(1);
                    ensure!(stride >= 1, "conv {layer}: stride must be >= 1");
                    ensure!(
                        h + 2 * pad >= k && w + 2 * pad >= k,
                        "conv {layer}: {k}x{k} kernel exceeds padded {h}x{w} input"
                    );
                    ensure!(
                        c % groups == 0,
                        "conv {layer}: {c} input channels not divisible by {groups} groups"
                    );
                    ensure!(
                        lw.out_ch % groups == 0,
                        "conv {layer}: {} filters not divisible by {groups} groups",
                        lw.out_ch
                    );
                    ensure!(
                        lw.rows == lw.out_ch,
                        "conv {layer}: weight rows {} != out channels {}",
                        lw.rows,
                        lw.out_ch
                    );
                    let ch_per_group = c / groups;
                    ensure!(
                        ch_per_group * k * k == lw.cols,
                        "conv {layer}: im2col cols {} != weight cols {}",
                        ch_per_group * k * k,
                        lw.cols
                    );
                    let oh = out_dim(h, k, stride, pad);
                    let ow = out_dim(w, k, stride, pad);
                    let out_kind = SlotKind::T4 { c: lw.out_ch, h: oh, w: ow };
                    let out_id = define(&mut slots, &mut index, out, out_kind);
                    let chunks = if groups == 1 {
                        chunk_tasks(&layer_parts[li], layer_knobs[li].chunk_rows)
                    } else {
                        Vec::new()
                    };
                    ops.push(PlanOp::Conv {
                        micro_rows: layer_knobs[li].micro_rows,
                        tile_cols: layer_knobs[li].tile_cols,
                        layer: li,
                        input: in_id,
                        out: out_id,
                        relu: *relu,
                        in_c: c,
                        in_h: h,
                        in_w: w,
                        oh,
                        ow,
                        k,
                        stride,
                        pad,
                        groups,
                        ch_per_group,
                        filt_per_group: lw.out_ch / groups,
                        chunks,
                        in_codes: false,
                        out_quant: None,
                        implicit: false,
                        panel_positions: 0,
                        in_nhwc: false,
                        out_nhwc: false,
                        fused_add: None,
                        group_chunks: Vec::new(),
                    });
                }
                OpMeta::Linear { layer, input, out } => {
                    manifest.layer(layer)?;
                    let li = weights.layer_index(layer)?;
                    let lw = &weights.layers[li];
                    let (in_id, kind) = read(&slots, &index, input)?;
                    let SlotKind::M { cols } = kind else {
                        return Err(err!("linear {layer}: input {input} is not a 2-D buffer"));
                    };
                    ensure!(
                        cols == lw.cols,
                        "linear {layer}: input cols {cols} != weight cols {}",
                        lw.cols
                    );
                    let out_id =
                        define(&mut slots, &mut index, out, SlotKind::M {
                            cols: lw.rows,
                        });
                    ops.push(PlanOp::Linear {
                        layer: li,
                        input: in_id,
                        out: out_id,
                        in_cols: lw.cols,
                        out_cols: lw.rows,
                        chunks: chunk_tasks(&layer_parts[li], layer_knobs[li].chunk_rows),
                        in_codes: false,
                        out_quant: None,
                        micro_rows: layer_knobs[li].micro_rows,
                        tile_cols: layer_knobs[li].tile_cols,
                    });
                }
                OpMeta::Add { a, b, out, relu } => {
                    let (a_id, ka) = read(&slots, &index, a)?;
                    let (b_id, kb) = read(&slots, &index, b)?;
                    let (SlotKind::T4 { .. }, SlotKind::T4 { .. }) = (ka, kb) else {
                        return Err(err!("add {a}+{b}: operands must be 4-D buffers"));
                    };
                    ensure!(
                        ka.per_image() == kb.per_image(),
                        "add shape mismatch {a} {b}"
                    );
                    let out_id = define(&mut slots, &mut index, out, ka);
                    ops.push(PlanOp::Add {
                        a: a_id,
                        b: b_id,
                        out: out_id,
                        relu: *relu,
                        per_image: ka.per_image(),
                    });
                }
                OpMeta::Gap { input, out } => {
                    let (in_id, kind) = read(&slots, &index, input)?;
                    let SlotKind::T4 { c, h, w } = kind else {
                        return Err(err!("gap: input {input} is not a 4-D buffer"));
                    };
                    let out_id =
                        define(&mut slots, &mut index, out, SlotKind::M { cols: c });
                    ops.push(PlanOp::Gap { input: in_id, out: out_id, c, h, w });
                }
            }
        }

        let logits_slot = *index
            .get("logits")
            .ok_or_else(|| err!("program produced no 'logits' matrix"))?;
        let SlotKind::M { cols: logits_cols } = slots[logits_slot].kind else {
            return Err(err!("program produced no 'logits' matrix"));
        };

        Ok(Ir {
            weights,
            model: manifest.model.clone(),
            capacity,
            chunk_rows,
            panel_bytes: panel_bytes.max(1),
            layer_knobs,
            act_bits: manifest.act_bits,
            input_slot,
            input_chw,
            logits_slot,
            logits_cols,
            slots,
            ops,
            layer_parts,
        })
    }
}
