//! Coordinator benchmarks: batcher mechanics (no model), and — when
//! artifacts exist — serving latency/throughput at several batch policies,
//! the L3 analogue of Table 6's latency column.
//!
//! Run after `make artifacts`: `cargo bench --bench bench_coordinator`

use std::hint::black_box;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use rmsmp::coordinator::batcher::{BatchPolicy, Batcher, Pending};
use rmsmp::coordinator::{Server, ServerConfig};
use rmsmp::gemm::ParallelConfig;
use rmsmp::model::{Manifest, ModelWeights};
use rmsmp::util::bench::Bench;

fn main() {
    let mut b = Bench::new("coordinator");

    // --- batcher mechanics (no model) --------------------------------------
    b.case("submit_dispatch_100", || {
        let batcher: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(100),
            queue_cap: 1024,
        });
        let (tx, _rx) = mpsc::channel();
        for i in 0..100u64 {
            let req = Pending {
                id: i,
                payload: 0,
                enqueued: Instant::now(),
                deadline: None,
                respond: tx.clone(),
            };
            batcher.submit(req).unwrap();
        }
        let mut n = 0;
        while n < 100 {
            n += batcher.next_batch().unwrap().requests.len();
        }
        black_box(n);
    });

    // --- end-to-end serving (needs artifacts) -------------------------------
    let dir = rmsmp::runtime::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("bench coordinator/serve_*: skipped (run `make artifacts`)");
        return;
    }
    let manifest = Manifest::load(&dir.join("manifest.json")).unwrap();
    let weights = ModelWeights::load(&dir.join("weights.bin")).unwrap();
    let image_len =
        manifest.input_shape[1] * manifest.input_shape[2] * manifest.input_shape[3];

    for (name, max_batch) in [("serve_batch1", 1usize), ("serve_batch8", 8)] {
        let server = Server::start(
            manifest.clone(),
            weights.clone(),
            ServerConfig {
                workers: 1,
                policy: BatchPolicy {
                    max_batch,
                    max_wait: Duration::from_millis(1),
                    queue_cap: 256,
                },
                parallel: ParallelConfig::sequential(),
            },
        )
        .unwrap();
        let img: Vec<f32> = (0..image_len).map(|i| (i % 13) as f32 / 13.0).collect();
        // one request per iteration, measured end to end (batch=8 submits 8)
        b.case_ops(name, Some(max_batch as f64), || {
            let rxs: Vec<_> = (0..max_batch)
                .map(|_| server.submit(img.clone()).unwrap())
                .collect();
            for rx in rxs {
                black_box(rx.recv().unwrap());
            }
        });
        println!("  {}", server.metrics.summary());
        server.shutdown();
    }
}
