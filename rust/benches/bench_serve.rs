//! HTTP serving benchmark: concurrent socket clients against the real
//! front-end, measuring per-request latency (p50/p99) and throughput at
//! several concurrency levels — the continuous-batching curve. A
//! synthetic in-memory model keeps the bench artifact-free so CI always
//! runs it; `RMSMP_BENCH_FAST=1` shrinks the request counts.
//!
//! Also measures the lazy JSON field scan against the tree parser on a
//! realistic request body (the ADR-002 claim: partial extraction should
//! be an order of magnitude faster than building the tree).
//!
//! Writes `BENCH_serve.json` (levels + batching speedup + parse
//! speedup) for the CI bench artifact upload.

use std::time::{Duration, Instant};

use rmsmp::coordinator::batcher::BatchPolicy;
use rmsmp::coordinator::{HttpConfig, HttpServer, Server, ServerConfig, SimpleClient};
use rmsmp::gemm::{PackedWeights, ParallelConfig, SortedWeights};
use rmsmp::model::manifest::Manifest;
use rmsmp::model::weights::{LayerWeights, ModelWeights};
use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::util::bench::Bench;
use rmsmp::util::json::{self, Json};
use rmsmp::util::rng::Rng;
use rmsmp::util::stats::percentile_sorted;

/// Synthetic gap→linear model (no artifacts needed): input (4, 8, 8),
/// 10 classes, mixed row schemes like the paper's 65:30:5 split.
fn synthetic() -> (Manifest, ModelWeights) {
    let manifest = Manifest::from_json(
        &Json::parse(
            r#"{
        "model": "bench", "arch": "resnet", "num_classes": 10,
        "input_shape": [1, 4, 8, 8], "ratio": [65, 30, 5], "act_bits": 4,
        "layers": [
          {"name": "fc", "kind": "linear", "rows": 10, "cols": 4,
           "stride": 0, "pad": 0, "groups": 1, "a_alpha": 1.0,
           "scheme_counts": [6, 3, 1, 0]}
        ],
        "program": [
          {"op": "gap", "in": "in0", "out": "b0"},
          {"op": "linear", "layer": "fc", "in": "b0", "out": "logits"}
        ]
      }"#,
        )
        .unwrap(),
    )
    .unwrap();
    let mut schemes = vec![Scheme::PotW4A4; 6];
    schemes.extend(vec![Scheme::FixedW4A4; 3]);
    schemes.push(Scheme::FixedW8A4);
    let mut rng = Rng::new(7);
    let w = Mat::from_vec(10, 4, rng.normal_vec(40, 0.5));
    let alpha: Vec<f32> = (0..10).map(|r| quant::default_alpha(w.row(r))).collect();
    let packed = PackedWeights::quantize(&w, &schemes, &alpha);
    let sorted = SortedWeights::from_packed(&packed);
    let weights = ModelWeights {
        layers: vec![LayerWeights {
            name: "fc".into(),
            kind: "linear".into(),
            rows: 10,
            cols: 4,
            out_ch: 10,
            in_ch: 4,
            kh: 1,
            kw: 1,
            stride: 0,
            pad: 0,
            groups: 1,
            a_alpha: 1.0,
            scheme: schemes,
            alpha,
            bias: vec![0.0; 10],
            w: Some(w),
            packed,
            sorted,
        }],
    };
    (manifest, weights)
}

fn request_body(input_len: usize) -> String {
    use std::fmt::Write as _;
    let mut body = String::with_capacity(input_len * 10 + 32);
    body.push_str("{\"input\":[");
    for i in 0..input_len {
        if i > 0 {
            body.push(',');
        }
        let _ = write!(body, "{}", (i % 13) as f32 / 13.0);
    }
    body.push_str("]}");
    body
}

/// Run `clients` concurrent keep-alive clients, `per_client` requests
/// each; returns (p50_ms, p99_ms, rps).
fn run_level(addr: &str, body: &str, clients: usize, per_client: usize) -> (f64, f64, f64) {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|_| {
            let addr = addr.to_string();
            let body = body.to_string();
            std::thread::spawn(move || {
                let mut c = SimpleClient::connect(&addr).expect("connect");
                let mut lat = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let t = Instant::now();
                    let resp = c.request("POST", "/v1/infer", &body).expect("request");
                    assert_eq!(resp.status, 200, "{}", resp.body);
                    lat.push(t.elapsed().as_secs_f64() * 1e3);
                }
                lat
            })
        })
        .collect();
    let mut lat: Vec<f64> = Vec::with_capacity(clients * per_client);
    for h in handles {
        lat.extend(h.join().expect("client thread"));
    }
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (
        percentile_sorted(&lat, 50.0),
        percentile_sorted(&lat, 99.0),
        lat.len() as f64 / wall,
    )
}

fn main() {
    let fast = std::env::var("RMSMP_BENCH_FAST").is_ok();
    let per_client = if fast { 20 } else { 200 };
    let levels = [1usize, 8, 32];

    // --- lazy JSON scan vs tree parse on a realistic body ------------------
    let (manifest, weights) = synthetic();
    let input_len = manifest.input_shape[1] * manifest.input_shape[2] * manifest.input_shape[3];
    let body = request_body(input_len);
    let mut b = Bench::new("serve");
    b.case("parse_tree", || {
        let j = Json::parse(&body).unwrap();
        std::hint::black_box(j.get("input").unwrap().as_f32_vec().unwrap());
    });
    let mut out = Vec::with_capacity(input_len);
    b.case("parse_lazy", || {
        json::lazy_f32_array(body.as_bytes(), "input", &mut out).unwrap();
        std::hint::black_box(out.len());
    });
    let parse_speedup = b.get("parse_tree").unwrap().ns_per_iter()
        / b.get("parse_lazy").unwrap().ns_per_iter();
    println!("bench serve/parse_speedup lazy is {parse_speedup:.1}x tree");

    // --- concurrent clients vs the real server -----------------------------
    let server = Server::start(
        manifest,
        weights,
        ServerConfig {
            workers: 1,
            policy: BatchPolicy {
                max_batch: 32,
                max_wait: Duration::from_millis(1),
                queue_cap: 4096,
            },
            parallel: ParallelConfig::sequential(),
        },
    )
    .unwrap();
    let http = HttpServer::start(
        server,
        HttpConfig {
            conn_threads: levels.iter().copied().max().unwrap() + 1,
            ..HttpConfig::default()
        },
    )
    .unwrap();
    let addr = http.addr().to_string();

    // warm the connection path + plan before measuring
    run_level(&addr, &body, 2, 5);

    let mut level_objs = Vec::new();
    let mut rps_by_level = Vec::new();
    for &clients in &levels {
        let (p50, p99, rps) = run_level(&addr, &body, clients, per_client);
        println!(
            "bench serve/clients{clients} p50 {p50:.3}ms p99 {p99:.3}ms thrpt {rps:.0} req/s"
        );
        level_objs.push(json::obj(vec![
            ("clients", json::num(clients as f64)),
            ("requests", json::num((clients * per_client) as f64)),
            ("p50_ms", json::num(p50)),
            ("p99_ms", json::num(p99)),
            ("rps", json::num(rps)),
        ]));
        rps_by_level.push((clients, rps));
    }
    let rps_at = |n: usize| {
        rps_by_level
            .iter()
            .find(|(c, _)| *c == n)
            .map(|(_, r)| *r)
            .unwrap_or(0.0)
    };
    let batching_speedup = rps_at(32) / rps_at(1).max(1e-9);
    println!("bench serve/batching_speedup_32v1 {batching_speedup:.2}x");
    println!("  {}", http.summary());
    http.shutdown();

    let path = b
        .write_json(vec![
            ("levels", Json::Arr(level_objs)),
            ("batching_speedup_32v1", json::num(batching_speedup)),
            ("parse_speedup", json::num(parse_speedup)),
        ])
        .unwrap();
    println!("wrote {}", path.display());
}
