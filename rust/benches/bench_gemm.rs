//! GEMM core benchmarks — the software twins of Table 6's heterogeneous
//! cores, at the paper's ResNet-18 layer shapes. Reports Gmac/s per core
//! (ops = MACs here) and the end-to-end mixed GEMM at the RMSMP ratio.
//!
//! Run: `cargo bench --bench bench_gemm`

use std::hint::black_box;

use rmsmp::gemm::cores::{GemmCore, GemmFixed4, GemmFixed8, GemmPoT4};
use rmsmp::gemm::{MixedGemm, PackedActs, PackedWeights, RowPartition};
use rmsmp::quant::{default_alpha, Mat, Scheme};
use rmsmp::util::bench::Bench;
use rmsmp::util::rng::Rng;

fn problem(rows: usize, cols: usize, batch: usize, scheme: Option<Scheme>, seed: u64)
    -> (PackedActs, PackedWeights, RowPartition) {
    let mut rng = Rng::new(seed);
    let x = Mat::from_vec(batch, cols, (0..batch * cols).map(|_| rng.uniform(0.0, 1.0)).collect());
    let w = Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 0.5));
    let alpha: Vec<f32> = (0..rows).map(|r| default_alpha(w.row(r))).collect();
    let schemes: Vec<Scheme> = match scheme {
        Some(s) => vec![s; rows],
        None => (0..rows)
            .map(|r| {
                // 65:30:5 layout
                if r * 100 < rows * 65 {
                    Scheme::PotW4A4
                } else if r * 100 < rows * 95 {
                    Scheme::FixedW4A4
                } else {
                    Scheme::FixedW8A4
                }
            })
            .collect(),
    };
    let acts = PackedActs::quantize(&x, 1.0, 4);
    let pw = PackedWeights::quantize(&w, &schemes, &alpha);
    let part = RowPartition::from_schemes(&schemes);
    (acts, pw, part)
}

fn main() {
    let mut b = Bench::new("gemm");
    // s2b0.conv2-like layer at CIFAR scale: 64 filters x 576, 256 positions
    let (rows, cols, batch) = (64, 576, 256);
    let macs = (rows * cols * batch) as f64;

    for (name, scheme) in [
        ("fixed4_core", Scheme::FixedW4A4),
        ("fixed8_core", Scheme::FixedW8A4),
        ("pot4_core", Scheme::PotW4A4),
    ] {
        let (acts, pw, _) = problem(rows, cols, batch, Some(scheme), 7);
        let core: &dyn GemmCore = match scheme {
            Scheme::FixedW4A4 => &GemmFixed4,
            Scheme::FixedW8A4 => &GemmFixed8,
            _ => &GemmPoT4,
        };
        let mut out = vec![0.0f32; batch];
        b.case_ops(name, Some(macs), || {
            for r in 0..rows {
                out.iter_mut().for_each(|v| *v = 0.0);
                core.run_row(black_box(&acts), black_box(&pw), r, &mut out);
            }
            black_box(&out);
        });
    }

    // mixed GEMM at the RMSMP ratio (the serving hot path)
    let (acts, pw, part) = problem(rows, cols, batch, None, 9);
    let g = MixedGemm::new();
    b.case_ops("mixed_65_30_5", Some(macs), || {
        black_box(g.run_partitioned(black_box(&acts), black_box(&pw), &part));
    });

    // packing cost (quantize activations + weights)
    let mut rng = Rng::new(11);
    let x = Mat::from_vec(batch, cols, (0..batch * cols).map(|_| rng.uniform(0.0, 1.0)).collect());
    b.case_ops("pack_acts", Some((batch * cols) as f64), || {
        black_box(PackedActs::quantize(black_box(&x), 1.0, 4));
    });
}
