//! GEMM core benchmarks — the software twins of Table 6's heterogeneous
//! cores, at the paper's ResNet-18 layer shapes, plus the parallel
//! mixed-GEMM speedup and the scalar-vs-SIMD / row-vs-block kernel
//! comparisons the CI bench-regression job tracks.
//!
//! Emits `BENCH_gemm.json` (ns/op per case, per scheme class, sequential
//! vs parallel, the 512^3 parallel speedup, `simd_speedup` — the
//! single-thread 512^3 win of the class-sorted SIMD block kernels over
//! the row-at-a-time scalar baseline — plus one
//! `simd_speedup_<tier>` per ISA tier the host supports and the
//! blocking parameters the load-time autotuner picks for the 512^3
//! shape) via `util::bench::Bench`.
//!
//! Run: `cargo bench --bench bench_gemm` (RMSMP_BENCH_FAST=1 for CI).

use std::hint::black_box;

use rmsmp::gemm::cores::{GemmCore, GemmFixed4, GemmFixed8, GemmPoT4};
use rmsmp::gemm::{
    autotune, chunk_tasks, GemmActs, GemmCall, GemmOut, GemmScratch, Isa, MixedGemm,
    PackedActs, PackedWeights, ParallelConfig, RowPartition, SortedWeights, TaskChunk,
    TuneShape, ISA_LADDER, MICRO_ROWS_CANDIDATES,
};
use rmsmp::quant::{default_alpha, Mat, Scheme};
use rmsmp::util::bench::Bench;
use rmsmp::util::json::{num, s};
use rmsmp::util::rng::Rng;

fn problem(
    rows: usize,
    cols: usize,
    batch: usize,
    scheme: Option<Scheme>,
    seed: u64,
) -> (PackedActs, PackedWeights, RowPartition) {
    let mut rng = Rng::new(seed);
    let xd: Vec<f32> = (0..batch * cols).map(|_| rng.uniform(0.0, 1.0)).collect();
    let x = Mat::from_vec(batch, cols, xd);
    let w = Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 0.5));
    let alpha: Vec<f32> = (0..rows).map(|r| default_alpha(w.row(r))).collect();
    let schemes: Vec<Scheme> = match scheme {
        Some(s) => vec![s; rows],
        None => (0..rows)
            .map(|r| {
                // 65:30:5 layout
                if r * 100 < rows * 65 {
                    Scheme::PotW4A4
                } else if r * 100 < rows * 95 {
                    Scheme::FixedW4A4
                } else {
                    Scheme::FixedW8A4
                }
            })
            .collect(),
    };
    let acts = PackedActs::quantize(&x, 1.0, 4);
    let pw = PackedWeights::quantize(&w, &schemes, &alpha);
    let part = RowPartition::from_schemes(&schemes);
    (acts, pw, part)
}

/// One mixed-GEMM dispatch through the single public entry point, into
/// a preallocated output (the benches never time the allocator).
#[allow(clippy::too_many_arguments)]
fn run_mixed(
    g: &MixedGemm,
    acts: &PackedActs,
    sw: &SortedWeights,
    chunks: &[TaskChunk],
    parallel: bool,
    scratch: &mut GemmScratch,
    out: &mut Mat,
) {
    g.dispatch(
        GemmCall {
            acts: GemmActs::Packed(acts),
            weights: sw,
            chunks,
            parallel,
            fill: true,
            out: GemmOut::F32(out),
        },
        scratch,
    );
}

fn main() {
    let mut b = Bench::new("gemm");
    // s2b0.conv2-like layer at CIFAR scale: 64 filters x 576, 256 positions
    let (rows, cols, batch) = (64, 576, 256);
    let macs = (rows * cols * batch) as f64;

    for (name, scheme) in [
        ("fixed4_core", Scheme::FixedW4A4),
        ("fixed8_core", Scheme::FixedW8A4),
        ("pot4_core", Scheme::PotW4A4),
    ] {
        let (acts, pw, _) = problem(rows, cols, batch, Some(scheme), 7);
        let core: &dyn GemmCore = match scheme {
            Scheme::FixedW4A4 => &GemmFixed4,
            Scheme::FixedW8A4 => &GemmFixed8,
            _ => &GemmPoT4,
        };
        let mut out = vec![0.0f32; batch];
        let mut acc = vec![0i32; batch];
        b.case_ops(name, Some(macs), || {
            for r in 0..rows {
                out.fill(0.0);
                core.run_row_tiled(black_box(&acts), black_box(&pw), r, 256, &mut acc, &mut out);
            }
            black_box(&out);
        });
    }

    // mixed GEMM at the RMSMP ratio (the serving hot path), seq vs parallel
    let threads = ParallelConfig::default().resolved_threads();
    let par = MixedGemm::with_config(ParallelConfig::default());
    let mut par_scratch = GemmScratch::new(par.lanes());
    {
        let (acts, pw, _) = problem(rows, cols, batch, None, 9);
        let sw = SortedWeights::from_packed(&pw);
        let chunks = chunk_tasks(sw.partition(), par.config().min_rows_per_task);
        let mut out = Mat::zeros(batch, rows);
        b.case_ops("mixed_65_30_5_seq", Some(macs), || {
            run_mixed(&par, black_box(&acts), &sw, &chunks, false, &mut par_scratch, &mut out);
            black_box(&out);
        });
        b.case_ops("mixed_65_30_5_par", Some(macs), || {
            run_mixed(&par, black_box(&acts), &sw, &chunks, true, &mut par_scratch, &mut out);
            black_box(&out);
        });
    }

    // the acceptance shape: 512 x 512 x 512 mixed-scheme GEMM
    let (b512, r512, c512) = (512, 512, 512);
    let macs512 = (b512 * r512 * c512) as f64;
    let (acts, pw, _) = problem(r512, c512, b512, None, 13);
    let sw512 = SortedWeights::from_packed(&pw);
    let chunks512 = chunk_tasks(sw512.partition(), par.config().min_rows_per_task);
    let mut out512 = Mat::zeros(b512, r512);
    b.case_ops("mixed512_seq", Some(macs512), || {
        run_mixed(&par, black_box(&acts), &sw512, &chunks512, false, &mut par_scratch, &mut out512);
        black_box(&out512);
    });
    b.case_ops("mixed512_par", Some(macs512), || {
        run_mixed(&par, black_box(&acts), &sw512, &chunks512, true, &mut par_scratch, &mut out512);
        black_box(&out512);
    });
    let seq_ns = b.get("mixed512_seq").map(|m| m.ns_per_iter()).unwrap_or(f64::NAN);
    let par_ns = b.get("mixed512_par").map(|m| m.ns_per_iter()).unwrap_or(f64::NAN);
    let speedup = seq_ns / par_ns;
    println!("bench gemm/mixed512 speedup: {speedup:.2}x at {threads} threads");

    // kernel-generation comparison at 512^3, all single-thread:
    //   row_scalar   — the PR 2 baseline: run_row_tiled per row, unsorted
    //   block_scalar — class-sorted layout + micro-kernel blocks, scalar dot
    //   block_simd   — same blocks on the detected SIMD ISA
    let isa = Isa::detect();
    let single = ParallelConfig { threads: 1, ..ParallelConfig::default() };
    let mut scalar_engine = MixedGemm::with_config(single);
    scalar_engine.set_isa(Isa::Scalar);
    let mut simd_engine = MixedGemm::with_config(single);
    simd_engine.set_isa(isa);
    let sw = SortedWeights::from_packed(&pw);
    let chunks = chunk_tasks(sw.partition(), single.min_rows_per_task);
    let mut scratch = GemmScratch::new(1);
    let mut out = Mat::zeros(b512, r512);
    {
        let mut acc = vec![0i32; b512];
        let mut col = vec![0.0f32; b512];
        b.case_ops("mixed512_row_scalar", Some(macs512), || {
            for r in 0..r512 {
                col.fill(0.0);
                scalar_engine.core_for(pw.scheme[r]).run_row_tiled(
                    black_box(&acts),
                    black_box(&pw),
                    r,
                    single.tile_cols,
                    &mut acc,
                    &mut col,
                );
                for (bi, &v) in col.iter().enumerate() {
                    out.set(bi, r, v);
                }
            }
            black_box(&out);
        });
    }
    b.case_ops("mixed512_block_scalar", Some(macs512), || {
        run_mixed(&scalar_engine, black_box(&acts), &sw, &chunks, false, &mut scratch, &mut out);
        black_box(&out);
    });
    b.case_ops("mixed512_block_simd", Some(macs512), || {
        run_mixed(&simd_engine, black_box(&acts), &sw, &chunks, false, &mut scratch, &mut out);
        black_box(&out);
    });
    // one case per non-scalar ladder tier the host actually supports
    // (the artifact shows which ran), all single-thread at 512^3
    let mut tier_cases: Vec<(String, String)> = Vec::new();
    for tier in ISA_LADDER {
        if tier == Isa::Scalar || tier.available() != tier {
            continue;
        }
        let mut tier_engine = MixedGemm::with_config(single);
        tier_engine.set_isa(tier);
        let case = format!("mixed512_block_{}", tier.name());
        b.case_ops(&case, Some(macs512), || {
            run_mixed(&tier_engine, black_box(&acts), &sw, &chunks, false, &mut scratch, &mut out);
            black_box(&out);
        });
        tier_cases.push((format!("simd_speedup_{}", tier.name()), case));
        // row-height sweep: the same tier at every tuned block height,
        // so the 4/6/8-row kernel ladder is visible per ISA in the
        // artifact (mr4 duplicates the default-engine case by design —
        // it anchors the sweep)
        for mr in MICRO_ROWS_CANDIDATES {
            let mut mr_engine =
                MixedGemm::with_config(ParallelConfig { micro_rows: mr, ..single });
            mr_engine.set_isa(tier);
            let case = format!("mixed512_block_{}_mr{}", tier.name(), mr);
            b.case_ops(&case, Some(macs512), || {
                run_mixed(&mr_engine, black_box(&acts), &sw, &chunks, false, &mut scratch, &mut out);
                black_box(&out);
            });
        }
    }
    let ns_of = |name: &str| b.get(name).map(|m| m.ns_per_iter()).unwrap_or(f64::NAN);
    let row_scalar_ns = ns_of("mixed512_row_scalar");
    let block_scalar_ns = ns_of("mixed512_block_scalar");
    let block_simd_ns = ns_of("mixed512_block_simd");
    // the acceptance metric: sorted blocks + SIMD vs the PR 2 scalar kernels
    let simd_speedup = row_scalar_ns / block_simd_ns;
    let block_speedup = row_scalar_ns / block_scalar_ns;
    let tier_speedups: Vec<(String, f64)> = tier_cases
        .iter()
        .map(|(key, case)| (key.clone(), row_scalar_ns / ns_of(case)))
        .collect();
    println!(
        "bench gemm/mixed512 kernels ({isa:?}): block {block_speedup:.2}x, \
         block+simd {simd_speedup:.2}x vs row-scalar"
    );

    // packing cost (quantize activations)
    let mut rng = Rng::new(11);
    let xd: Vec<f32> = (0..batch * cols).map(|_| rng.uniform(0.0, 1.0)).collect();
    let x = Mat::from_vec(batch, cols, xd);
    b.case_ops("pack_acts", Some((batch * cols) as f64), || {
        black_box(PackedActs::quantize(black_box(&x), 1.0, 4));
    });

    // what the load-time autotuner picks for the acceptance shape on
    // this machine (per-process cached — a plan compile for a model
    // with a 512^3-class layer reuses exactly this result)
    let tuned = autotune::tune(
        TuneShape::for_layer(r512, c512, b512),
        &ParallelConfig::default(),
        false,
    );
    println!(
        "bench gemm: autotuned mr {} / tile {} / chunk {} / panel {} B ({})",
        tuned.micro_rows,
        tuned.tile_cols,
        tuned.min_rows_per_task,
        tuned.panel_bytes,
        tuned.source.name()
    );

    let mut extra = vec![
        ("threads", num(threads as f64)),
        ("speedup_512", num(speedup)),
        ("isa", s(isa.name())),
        ("simd_speedup", num(simd_speedup)),
        ("block_speedup", num(block_speedup)),
        ("tuned_micro_rows", num(tuned.micro_rows as f64)),
        ("tuned_tile_cols", num(tuned.tile_cols as f64)),
        ("tuned_min_rows_per_task", num(tuned.min_rows_per_task as f64)),
        ("tuned_panel_bytes", num(tuned.panel_bytes as f64)),
        ("tuned_source", s(tuned.source.name())),
    ];
    for (key, v) in &tier_speedups {
        extra.push((key.as_str(), num(*v)));
    }
    match b.write_json(extra) {
        Ok(path) => println!("bench gemm: wrote {}", path.display()),
        Err(e) => eprintln!("bench gemm: could not write JSON: {e}"),
    }
}
