//! Quantizer microbenchmarks: per-element cost of each scheme's quantizer
//! and the row-wise mixed projector (the training-side hot path of Alg. 1).
//!
//! Emits `BENCH_quant.json` for the CI bench-regression artifact.
//!
//! Run: `cargo bench --bench bench_quant` (RMSMP_BENCH_FAST=1 for CI).

use std::hint::black_box;

use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::util::bench::Bench;
use rmsmp::util::rng::Rng;

fn main() {
    let mut b = Bench::new("quant");
    let n = 64 * 1024;
    let mut rng = Rng::new(1);
    let w: Vec<f32> = rng.normal_vec(n, 0.5);

    b.case_ops("fixed4", Some(n as f64), || {
        let mut acc = 0.0f32;
        for &v in &w {
            acc += quant::fixed_quant(black_box(v), 1.0, 4);
        }
        black_box(acc);
    });
    b.case_ops("fixed8", Some(n as f64), || {
        let mut acc = 0.0f32;
        for &v in &w {
            acc += quant::fixed_quant(black_box(v), 1.0, 8);
        }
        black_box(acc);
    });
    b.case_ops("pot4", Some(n as f64), || {
        let mut acc = 0.0f32;
        for &v in &w {
            acc += quant::pot_quant(black_box(v), 1.0, 4);
        }
        black_box(acc);
    });
    let apot = quant::apot::ApotQuantizer::new(4);
    b.case_ops("apot4", Some(n as f64), || {
        let mut acc = 0.0f32;
        for &v in &w {
            acc += apot.quant(black_box(v), 1.0);
        }
        black_box(acc);
    });
    b.case_ops("act4", Some(n as f64), || {
        let mut acc = 0.0f32;
        for &v in &w {
            acc += quant::act_quant(black_box(v), 1.0, 4);
        }
        black_box(acc);
    });

    // row-wise mixed projector on a realistic layer (64 x 576 @ 65:30:5)
    let (rows, cols) = (64, 576);
    let wm = Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 0.5));
    let alpha: Vec<f32> = (0..rows).map(|r| quant::default_alpha(wm.row(r))).collect();
    let schemes: Vec<Scheme> = (0..rows)
        .map(|r| {
            if r < 42 {
                Scheme::PotW4A4
            } else if r < 61 {
                Scheme::FixedW4A4
            } else {
                Scheme::FixedW8A4
            }
        })
        .collect();
    b.case_ops("rowwise/64x576", Some((rows * cols) as f64), || {
        black_box(quant::rowwise_quant(black_box(&wm), &alpha, &schemes));
    });

    match b.write_json(vec![]) {
        Ok(path) => println!("bench quant: wrote {}", path.display()),
        Err(e) => eprintln!("bench quant: could not write JSON: {e}"),
    }
}
