//! FPGA simulator benchmarks + the Table 6 regeneration timing.
//!
//! The simulator itself is microseconds per config; this bench pins that
//! (so sweeps stay interactive) and regenerates the headline speedup.
//!
//! Run: `cargo bench --bench bench_fpga`

use std::hint::black_box;

use rmsmp::fpga::{simulate, Board, CoreCosts, Design, QuantConfig};
use rmsmp::quant::Ratio;
use rmsmp::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fpga");
    let layers = rmsmp::fpga::sim::resnet18_imagenet_layers();

    b.case("allocate", || {
        black_box(Design::allocate(
            Board::XC7Z045,
            QuantConfig { ratio: Ratio::RMSMP2, first_last_8bit: false, apot: false },
            CoreCosts::default(),
        ));
    });

    let d = Design::allocate(
        Board::XC7Z045,
        QuantConfig { ratio: Ratio::RMSMP2, first_last_8bit: false, apot: false },
        CoreCosts::default(),
    );
    b.case("simulate_resnet18", || {
        black_box(simulate(black_box(&d), black_box(&layers)));
    });

    b.case("ratio_sweep_21", || {
        for pot in 0..21u32 {
            let d = Design::allocate(
                Board::XC7Z045,
                QuantConfig {
                    ratio: Ratio::new(pot * 4 + 5, 90 - pot * 4, 5),
                    first_last_8bit: false,
                    apot: false,
                },
                CoreCosts::default(),
            );
            black_box(simulate(&d, &layers));
        }
    });

    // headline numbers, printed for EXPERIMENTS.md
    let fixed = Design::allocate(
        Board::XC7Z045,
        QuantConfig { ratio: Ratio::new(0, 100, 0), first_last_8bit: true, apot: false },
        CoreCosts::default(),
    );
    let r_fixed = simulate(&fixed, &layers);
    let r_rmsmp = simulate(&d, &layers);
    println!(
        "table6/headline: RMSMP-2 {:.1} GOP/s {:.1} ms vs Fixed {:.1} GOP/s {:.1} ms => {:.2}x (paper 3.65x)",
        r_rmsmp.gops, r_rmsmp.latency_ms, r_fixed.gops, r_fixed.latency_ms,
        r_fixed.latency_ms / r_rmsmp.latency_ms
    );
}
