//! Runtime benchmarks: the integer executor through the native runtime —
//! compiled plan vs the reference interpreter at batch 1 and 8, plus one
//! ablation per optimizer pass (integer-resident vs f32-resident,
//! implicit vs explicit-im2col, fused vs standalone residual add,
//! depthwise specialization vs the grouped fallback), the per-layer
//! load-time autotuner's machine-tuned blocking vs the fixed defaults
//! (`autotune_speedup_b1/b8`) and vs a pinned 4-row block height
//! (`microrows_speedup_b1/b8`), the plan-compile cost and tune-cache
//! provenance (`plan_build_ms`, `tune_cache_hits/misses` — the CI
//! bench-smoke double-run asserts `tune_cache_misses == 0` on its
//! second, warm-cache pass), the model-load comparison between the
//! legacy parse-and-quantize path and the mapped `.rmsa` artifact
//! (`json_load_ms` / `artifact_load_ms` / `load_speedup` /
//! `artifact_bytes` — CI asserts the mapped path stays ≥10× faster),
//! and sequential vs parallel — on a
//! synthetic residual CNN (no artifacts needed) and, when artifacts
//! exist, on the shipped model. Writes `BENCH_runtime.json`
//! (per-inference latency + the ablation speedups) for the CI
//! bench-smoke artifact.
//!
//! Run: `cargo bench --bench bench_runtime` (RMSMP_BENCH_FAST=1 for CI).

use std::hint::black_box;
use std::sync::Arc;

use rmsmp::gemm::{PackedWeights, ParallelConfig, SortedWeights};
use rmsmp::model::manifest::Manifest;
use rmsmp::model::weights::{LayerWeights, ModelWeights};
use rmsmp::model::{Executor, Plan};
use rmsmp::quant::tensor::Tensor4;
use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::runtime::Runtime;
use rmsmp::util::bench::Bench;
use rmsmp::util::json::{num, s, Json};
use rmsmp::util::rng::Rng;

#[allow(clippy::too_many_arguments)]
fn layer(
    name: &str,
    kind: &str,
    conv: (usize, usize, usize, usize),
    stride: usize,
    pad: usize,
    groups: usize,
    w: Mat,
    schemes: Vec<Scheme>,
    alpha: Vec<f32>,
) -> LayerWeights {
    let packed = PackedWeights::quantize(&w, &schemes, &alpha);
    let sorted = SortedWeights::from_packed(&packed);
    LayerWeights {
        name: name.into(),
        kind: kind.into(),
        rows: w.rows,
        cols: w.cols,
        out_ch: conv.0,
        in_ch: conv.1,
        kh: conv.2,
        kw: conv.3,
        stride,
        pad,
        groups,
        a_alpha: 1.0,
        scheme: schemes,
        alpha,
        bias: vec![0.0; w.rows],
        w: Some(w),
        packed,
        sorted,
    }
}

/// A residual CNN big enough to time and wide enough to exercise every
/// optimizer pass: 32ch 16x16 input, a residual block (c1 -> c2, add
/// c1's output back with ReLU — the add the `epilogue_fusion` pass folds
/// into c2), a 64-group depthwise conv (the `depthwise` pass target),
/// one more 3x3 conv (its two integer-resident edges around the
/// depthwise conv carry u8 codes), gap, 10-way classifier.
const SYNTH_JSON: &str = r#"{
        "model": "bench", "arch": "resnet", "num_classes": 10,
        "input_shape": [4, 32, 16, 16], "ratio": [65, 30, 5], "act_bits": 4,
        "layers": [
          {"name": "c1", "kind": "conv", "rows": 64, "cols": 288,
           "stride": 1, "pad": 1, "groups": 1, "a_alpha": 1.0,
           "scheme_counts": [42, 19, 3, 0]},
          {"name": "c2", "kind": "conv", "rows": 64, "cols": 576,
           "stride": 1, "pad": 1, "groups": 1, "a_alpha": 1.0,
           "scheme_counts": [42, 19, 3, 0]},
          {"name": "dw", "kind": "conv", "rows": 64, "cols": 9,
           "stride": 1, "pad": 1, "groups": 64, "a_alpha": 1.0,
           "scheme_counts": [42, 19, 3, 0]},
          {"name": "c3", "kind": "conv", "rows": 64, "cols": 576,
           "stride": 1, "pad": 1, "groups": 1, "a_alpha": 1.0,
           "scheme_counts": [42, 19, 3, 0]},
          {"name": "fc", "kind": "linear", "rows": 10, "cols": 64,
           "stride": 0, "pad": 0, "groups": 1, "a_alpha": 1.0,
           "scheme_counts": [7, 3, 0, 0]}
        ],
        "program": [
          {"op": "conv", "layer": "c1", "in": "in0", "out": "b0", "relu": true},
          {"op": "conv", "layer": "c2", "in": "b0", "out": "b1", "relu": false},
          {"op": "add", "a": "b0", "b": "b1", "out": "b2", "relu": true},
          {"op": "conv", "layer": "dw", "in": "b2", "out": "b3", "relu": false},
          {"op": "conv", "layer": "c3", "in": "b3", "out": "b4", "relu": true},
          {"op": "gap", "in": "b4", "out": "b5"},
          {"op": "linear", "layer": "fc", "in": "b5", "out": "logits"}
        ]
      }"#;

fn synthetic_model() -> (Manifest, ModelWeights) {
    let manifest = Manifest::from_json(&Json::parse(SYNTH_JSON).unwrap()).unwrap();

    let mut rng = Rng::new(3);
    let mk = |rows: usize, cols: usize, rng: &mut Rng| -> (Mat, Vec<Scheme>, Vec<f32>) {
        let w = Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 0.4));
        let schemes: Vec<Scheme> = (0..rows)
            .map(|r| {
                if r * 100 < rows * 65 {
                    Scheme::PotW4A4
                } else if r * 100 < rows * 95 {
                    Scheme::FixedW4A4
                } else {
                    Scheme::FixedW8A4
                }
            })
            .collect();
        let alpha: Vec<f32> = (0..rows).map(|r| quant::default_alpha(w.row(r))).collect();
        (w, schemes, alpha)
    };
    let (wc, sc, ac) = mk(64, 288, &mut rng);
    let (wc2, sc2, ac2) = mk(64, 576, &mut rng);
    let (wd, sd, ad) = mk(64, 9, &mut rng);
    let (wc3, sc3, ac3) = mk(64, 576, &mut rng);
    let (wf, sf, af) = mk(10, 64, &mut rng);
    let layers = vec![
        layer("c1", "conv", (64, 32, 3, 3), 1, 1, 1, wc, sc, ac),
        layer("c2", "conv", (64, 64, 3, 3), 1, 1, 1, wc2, sc2, ac2),
        layer("dw", "conv", (64, 64, 3, 3), 1, 1, 64, wd, sd, ad),
        layer("c3", "conv", (64, 64, 3, 3), 1, 1, 1, wc3, sc3, ac3),
        layer("fc", "linear", (10, 64, 1, 1), 0, 0, 1, wf, sf, af),
    ];
    (manifest, ModelWeights { layers })
}

fn rand_input(shape: (usize, usize, usize, usize), seed: u64) -> Tensor4 {
    let (n, c, h, w) = shape;
    let mut rng = Rng::new(seed);
    let mut x = Tensor4::zeros(n, c, h, w);
    for v in x.data.iter_mut() {
        *v = rng.uniform(0.0, 1.0);
    }
    x
}

/// Plan-based inference (the production path).
fn bench_plan(b: &mut Bench, name: &str, exec: &mut Executor, x: &Tensor4) {
    b.case_ops(name, Some(x.n as f64), || {
        black_box(exec.infer(black_box(x)).unwrap());
    });
}

/// The name-resolving interpreter (the seed's per-call-allocating path).
fn bench_interp(b: &mut Bench, name: &str, exec: &mut Executor, x: &Tensor4) {
    b.case_ops(name, Some(x.n as f64), || {
        black_box(exec.reference_infer(black_box(x)).unwrap());
    });
}

fn ns(b: &Bench, name: &str) -> f64 {
    b.get(name).map(|m| m.ns_per_iter()).unwrap_or(f64::NAN)
}

/// An executor over the full plan minus one optimizer pass — the
/// per-pass ablation baseline.
fn ablated(
    manifest: &Manifest,
    weights: &ModelWeights,
    capacity: usize,
    cfg: ParallelConfig,
    pass: &str,
) -> Executor {
    let plan = Arc::new(
        Plan::builder(manifest, weights)
            .capacity(capacity)
            .config(&cfg)
            .disable_pass(pass)
            .build()
            .unwrap(),
    );
    Executor::from_shared(
        Arc::new(manifest.clone()),
        Arc::new(weights.clone()),
        plan,
        cfg,
        None,
    )
    .unwrap()
}

fn main() {
    let mut b = Bench::new("runtime");

    let seq_rt = Runtime::sequential();
    let par_rt = Runtime::new(ParallelConfig::default());
    println!("runtime: {} thread(s) in parallel config", par_rt.threads());

    let (manifest, weights) = synthetic_model();

    // the FIRST plan compile in this process: its tune-cache stats are
    // the cold/warm provenance signal (with RMSMP_TUNE_CACHE set, a
    // cold cache microbenches and persists, a warm cache answers every
    // layer signature with zero microbench dispatches) and its wall
    // time is the load-time cost a fleet pays per boot
    let capacity = manifest.input_shape.first().copied().unwrap_or(1);
    let build_cfg = seq_rt.config();
    let t0 = std::time::Instant::now();
    let first_plan = Plan::builder(&manifest, &weights)
        .capacity(capacity)
        .config(&build_cfg)
        .build()
        .unwrap();
    let plan_build_ms = t0.elapsed().as_secs_f64() * 1e3;
    let tune_stats = first_plan.tune_stats;
    drop(first_plan);
    println!(
        "bench runtime: plan build {plan_build_ms:.2} ms ({} tune-cache hit(s), \
         {} microbenched)",
        tune_stats.cache_hits, tune_stats.cache_misses
    );

    // plan vs interpreter, batch 1 and 8, sequential engine: the
    // compile-then-run payoff per inference
    let mut seq = seq_rt.executor(manifest.clone(), weights.clone()).unwrap();
    let x1 = rand_input((1, 32, 16, 16), 5);
    let x8 = rand_input((8, 32, 16, 16), 6);
    bench_interp(&mut b, "interp_b1", &mut seq, &x1);
    bench_plan(&mut b, "plan_b1", &mut seq, &x1);
    bench_interp(&mut b, "interp_b8", &mut seq, &x8);
    bench_plan(&mut b, "plan_b8", &mut seq, &x8);
    let speedup_b1 = ns(&b, "interp_b1") / ns(&b, "plan_b1");
    let speedup_b8 = ns(&b, "interp_b8") / ns(&b, "plan_b8");
    println!("bench runtime: plan speedup {speedup_b1:.2}x @ batch 1, {speedup_b8:.2}x @ batch 8");

    // per-pass ablations: the full plan above vs the same plan with one
    // optimizer pass disabled (same engine, same kernels — only the
    // rewrite under test differs)
    let cfg = seq_rt.config();

    // integer-resident dataflow: the end-to-end win of fusing
    // requantization into the GEMM epilogue
    let mut f32_seq = ablated(&manifest, &weights, capacity, cfg, "integer_resident");
    bench_plan(&mut b, "f32res_b1", &mut f32_seq, &x1);
    bench_plan(&mut b, "f32res_b8", &mut f32_seq, &x8);
    let requant_speedup_b1 = ns(&b, "f32res_b1") / ns(&b, "plan_b1");
    let requant_speedup_b8 = ns(&b, "f32res_b8") / ns(&b, "plan_b8");
    println!(
        "bench runtime: requant-fusion speedup {requant_speedup_b1:.2}x @ batch 1, \
         {requant_speedup_b8:.2}x @ batch 8"
    );

    // implicit GEMM vs the explicit-im2col conv path: same
    // integer-resident domains — only the activation staging differs
    // (per-lane panels vs the materialized patch matrix)
    let mut exp_seq = ablated(&manifest, &weights, capacity, cfg, "implicit");
    bench_plan(&mut b, "explicit_b1", &mut exp_seq, &x1);
    bench_plan(&mut b, "explicit_b8", &mut exp_seq, &x8);
    let implicit_speedup_b1 = ns(&b, "explicit_b1") / ns(&b, "plan_b1");
    let implicit_speedup_b8 = ns(&b, "explicit_b8") / ns(&b, "plan_b8");
    let lanes = cfg.lanes();
    let implicit_fp = seq.plan().footprint(lanes).total_bytes();
    let explicit_fp = exp_seq.plan().footprint(lanes).total_bytes();
    println!(
        "bench runtime: implicit-GEMM speedup {implicit_speedup_b1:.2}x @ batch 1, \
         {implicit_speedup_b8:.2}x @ batch 8; workspace {implicit_fp} B vs explicit \
         {explicit_fp} B ({} B saved)",
        explicit_fp as i64 - implicit_fp as i64
    );

    // epilogue fusion: the residual add folded into c2's epilogue vs the
    // standalone Add op (which forces the conv output and both operands
    // through f32 slots)
    let mut nofuse_seq = ablated(&manifest, &weights, capacity, cfg, "epilogue_fusion");
    bench_plan(&mut b, "nofuse_b1", &mut nofuse_seq, &x1);
    bench_plan(&mut b, "nofuse_b8", &mut nofuse_seq, &x8);
    let fusion_speedup_b1 = ns(&b, "nofuse_b1") / ns(&b, "plan_b1");
    let fusion_speedup_b8 = ns(&b, "nofuse_b8") / ns(&b, "plan_b8");
    println!(
        "bench runtime: epilogue-fusion speedup {fusion_speedup_b1:.2}x @ batch 1, \
         {fusion_speedup_b8:.2}x @ batch 8"
    );

    // depthwise specialization: per-group streamed panel GEMMs vs the
    // row-by-row explicit grouped fallback
    let mut nodw_seq = ablated(&manifest, &weights, capacity, cfg, "depthwise");
    bench_plan(&mut b, "nodw_b1", &mut nodw_seq, &x1);
    bench_plan(&mut b, "nodw_b8", &mut nodw_seq, &x8);
    let depthwise_speedup_b1 = ns(&b, "nodw_b1") / ns(&b, "plan_b1");
    let depthwise_speedup_b8 = ns(&b, "nodw_b8") / ns(&b, "plan_b8");
    println!(
        "bench runtime: depthwise speedup {depthwise_speedup_b1:.2}x @ batch 1, \
         {depthwise_speedup_b8:.2}x @ batch 8"
    );

    // load-time autotuning: the machine-tuned blocking knobs baked into
    // the full plan vs the same plan compiled with the fixed defaults
    // (same passes, same kernels — only tile / chunk / panel sizing
    // differs; logits are bit-identical either way)
    let notune_plan = Arc::new(
        Plan::builder(&manifest, &weights)
            .capacity(capacity)
            .config(&cfg)
            .no_tune()
            .build()
            .unwrap(),
    );
    let mut notune_seq = Executor::from_shared(
        Arc::new(manifest.clone()),
        Arc::new(weights.clone()),
        notune_plan,
        cfg,
        None,
    )
    .unwrap();
    bench_plan(&mut b, "notune_b1", &mut notune_seq, &x1);
    bench_plan(&mut b, "notune_b8", &mut notune_seq, &x8);
    let autotune_speedup_b1 = ns(&b, "notune_b1") / ns(&b, "plan_b1");
    let autotune_speedup_b8 = ns(&b, "notune_b8") / ns(&b, "plan_b8");
    let tuned = seq.plan().tuned;
    println!(
        "bench runtime: autotune speedup {autotune_speedup_b1:.2}x @ batch 1, \
         {autotune_speedup_b8:.2}x @ batch 8 (mr {} / tile {} / chunk {} / panel {} B, {})",
        seq.plan().cfg.micro_rows,
        seq.plan().cfg.tile_cols,
        seq.plan().cfg.min_rows_per_task,
        tuned.panel_bytes,
        tuned.source.name()
    );

    // micro-kernel row-height ablation: the same fully-tuned plan with
    // the block height pinned at the old constant 4 (every other knob
    // still tunes) vs the free 4/6/8 sweep — isolates what the widened
    // kernel space itself buys on this machine
    let mr4_plan = Arc::new(
        Plan::builder(&manifest, &weights)
            .capacity(capacity)
            .config(&cfg)
            .pin_micro_rows(4)
            .build()
            .unwrap(),
    );
    let mut mr4_seq = Executor::from_shared(
        Arc::new(manifest.clone()),
        Arc::new(weights.clone()),
        mr4_plan,
        cfg,
        None,
    )
    .unwrap();
    bench_plan(&mut b, "mr4_b1", &mut mr4_seq, &x1);
    bench_plan(&mut b, "mr4_b8", &mut mr4_seq, &x8);
    let microrows_speedup_b1 = ns(&b, "mr4_b1") / ns(&b, "plan_b1");
    let microrows_speedup_b8 = ns(&b, "mr4_b8") / ns(&b, "plan_b8");
    println!(
        "bench runtime: micro-rows speedup {microrows_speedup_b1:.2}x @ batch 1, \
         {microrows_speedup_b8:.2}x @ batch 8 (tuned mr {})",
        seq.plan().cfg.micro_rows
    );

    // the compiled-plan dump (the `rmsmp plan` output for this model,
    // including the per-pass optimizer report): CI shows and uploads it
    // so footprint regressions are visible per PR. Same target directory
    // convention as Bench::write_json.
    let plan_dir = std::env::var("RMSMP_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
    let plan_path = std::path::Path::new(&plan_dir).join("PLAN_runtime.txt");
    match std::fs::write(&plan_path, seq.plan().describe(&weights, lanes)) {
        Ok(()) => println!("bench runtime: wrote {}", plan_path.display()),
        Err(e) => eprintln!("bench runtime: could not write {}: {e}", plan_path.display()),
    }

    // sequential vs parallel plan execution at the manifest batch
    let x4 = rand_input((4, 32, 16, 16), 7);
    let mut par = par_rt.executor(manifest, weights).unwrap();
    bench_plan(&mut b, "synthetic_seq", &mut seq, &x4);
    bench_plan(&mut b, "synthetic_par", &mut par, &x4);

    // the shipped model, when artifacts are present
    let dir = rmsmp::runtime::artifacts_dir();
    if dir.join("manifest.json").exists() {
        let manifest = rmsmp::model::Manifest::load(&dir.join("manifest.json")).unwrap();
        let weights = ModelWeights::load(&dir.join("weights.bin")).unwrap();
        let s = manifest.input_shape.clone();
        let shape = (s[0], s[1], s[2], s[3]);
        let mut seq = seq_rt.executor(manifest.clone(), weights.clone()).unwrap();
        let mut par = par_rt.executor(manifest, weights).unwrap();
        let xm = rand_input(shape, 8);
        bench_plan(&mut b, "model_seq", &mut seq, &xm);
        bench_plan(&mut b, "model_par", &mut par, &xm);
    } else {
        println!("bench runtime/model_*: skipped (run `make artifacts`)");
    }

    // model load paths: the legacy `weights.bin` parse (read floats,
    // quantize, class-sort — work re-done on every boot) vs the `.rmsa`
    // packed artifact (validate header + checksum, then alias the
    // already-sorted planes). Cold-load wall time per path, best of
    // several runs; `load_speedup` is the headline artifact win.
    let (_, weights2) = synthetic_model();
    let tmp = std::env::temp_dir();
    let pid = std::process::id();
    let bin_path = tmp.join(format!("rmsmp-bench-{pid}.bin"));
    let rmsa_path = tmp.join(format!("rmsmp-bench-{pid}.rmsa"));
    std::fs::write(&bin_path, weights2.to_weights_bin().unwrap()).unwrap();
    rmsmp::model::artifact::pack_to_file(SYNTH_JSON, &weights2, &rmsa_path).unwrap();
    let artifact_bytes = std::fs::metadata(&rmsa_path).unwrap().len();
    let mut json_load_ms = f64::INFINITY;
    let mut artifact_load_ms = f64::INFINITY;
    for _ in 0..20 {
        let t0 = std::time::Instant::now();
        black_box(ModelWeights::load(&bin_path).unwrap());
        json_load_ms = json_load_ms.min(t0.elapsed().as_secs_f64() * 1e3);
        let t0 = std::time::Instant::now();
        black_box(rmsmp::model::artifact::load(&rmsa_path).unwrap());
        artifact_load_ms = artifact_load_ms.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    let _ = std::fs::remove_file(&bin_path);
    let _ = std::fs::remove_file(&rmsa_path);
    let load_speedup = json_load_ms / artifact_load_ms;
    println!(
        "bench runtime: weights load {json_load_ms:.3} ms (parse+quantize) vs \
         {artifact_load_ms:.3} ms (.rmsa, {artifact_bytes} B) -> {load_speedup:.1}x"
    );

    let extra = vec![
        ("threads", num(par_rt.threads() as f64)),
        ("plan_speedup_b1", num(speedup_b1)),
        ("plan_speedup_b8", num(speedup_b8)),
        ("requant_speedup_b1", num(requant_speedup_b1)),
        ("requant_speedup_b8", num(requant_speedup_b8)),
        ("implicit_speedup_b1", num(implicit_speedup_b1)),
        ("implicit_speedup_b8", num(implicit_speedup_b8)),
        ("fusion_speedup_b1", num(fusion_speedup_b1)),
        ("fusion_speedup_b8", num(fusion_speedup_b8)),
        ("depthwise_speedup_b1", num(depthwise_speedup_b1)),
        ("depthwise_speedup_b8", num(depthwise_speedup_b8)),
        ("implicit_fp_bytes", num(implicit_fp as f64)),
        ("explicit_fp_bytes", num(explicit_fp as f64)),
        ("fp_saved_bytes", num(explicit_fp as f64 - implicit_fp as f64)),
        ("autotune_speedup_b1", num(autotune_speedup_b1)),
        ("autotune_speedup_b8", num(autotune_speedup_b8)),
        ("microrows_speedup_b1", num(microrows_speedup_b1)),
        ("microrows_speedup_b8", num(microrows_speedup_b8)),
        ("plan_build_ms", num(plan_build_ms)),
        ("tune_cache_hits", num(tune_stats.cache_hits as f64)),
        ("tune_cache_misses", num(tune_stats.cache_misses as f64)),
        ("tuned_micro_rows", num(seq.plan().cfg.micro_rows as f64)),
        ("tuned_tile_cols", num(tuned.tile_cols as f64)),
        ("tuned_min_rows_per_task", num(tuned.min_rows_per_task as f64)),
        ("tuned_panel_bytes", num(tuned.panel_bytes as f64)),
        ("tuned_source", s(tuned.source.name())),
        ("json_load_ms", num(json_load_ms)),
        ("artifact_load_ms", num(artifact_load_ms)),
        ("load_speedup", num(load_speedup)),
        ("artifact_bytes", num(artifact_bytes as f64)),
    ];
    match b.write_json(extra) {
        Ok(path) => println!("bench runtime: wrote {}", path.display()),
        Err(e) => eprintln!("bench runtime: could not write JSON: {e}"),
    }
}
