//! Runtime benchmarks: the integer executor through the native runtime,
//! sequential vs parallel, on a synthetic CNN (no artifacts needed) and —
//! when artifacts exist — on the shipped model. (The PJRT/XLA float leg
//! moved to the Python side with the zero-dependency build.)
//!
//! Run: `cargo bench --bench bench_runtime` (RMSMP_BENCH_FAST=1 for CI).

use std::hint::black_box;

use rmsmp::gemm::{PackedWeights, ParallelConfig};
use rmsmp::model::manifest::Manifest;
use rmsmp::model::weights::{LayerWeights, ModelWeights};
use rmsmp::model::Executor;
use rmsmp::quant::tensor::Tensor4;
use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::runtime::Runtime;
use rmsmp::util::bench::Bench;
use rmsmp::util::json::Json;
use rmsmp::util::rng::Rng;

fn layer(
    name: &str,
    kind: &str,
    conv: (usize, usize, usize, usize),
    stride: usize,
    pad: usize,
    w: Mat,
    schemes: Vec<Scheme>,
    alpha: Vec<f32>,
) -> LayerWeights {
    let packed = PackedWeights::quantize(&w, &schemes, &alpha);
    LayerWeights {
        name: name.into(),
        kind: kind.into(),
        rows: w.rows,
        cols: w.cols,
        out_ch: conv.0,
        in_ch: conv.1,
        kh: conv.2,
        kw: conv.3,
        stride,
        pad,
        groups: 1,
        a_alpha: 1.0,
        scheme: schemes,
        alpha,
        bias: vec![0.0; w.rows],
        w,
        packed,
    }
}

/// A conv -> gap -> linear model big enough to time: 32ch 16x16 input,
/// 64-filter 3x3 conv, 10-way classifier.
fn synthetic_model() -> (Manifest, ModelWeights) {
    let manifest = Manifest::from_json(
        &Json::parse(
            r#"{
        "model": "bench", "arch": "resnet", "num_classes": 10,
        "input_shape": [4, 32, 16, 16], "ratio": [65, 30, 5], "act_bits": 4,
        "layers": [
          {"name": "c1", "kind": "conv", "rows": 64, "cols": 288,
           "stride": 1, "pad": 1, "groups": 1, "a_alpha": 1.0,
           "scheme_counts": [42, 19, 3, 0]},
          {"name": "fc", "kind": "linear", "rows": 10, "cols": 64,
           "stride": 0, "pad": 0, "groups": 1, "a_alpha": 1.0,
           "scheme_counts": [7, 3, 0, 0]}
        ],
        "program": [
          {"op": "conv", "layer": "c1", "in": "in0", "out": "b0", "relu": true},
          {"op": "gap", "in": "b0", "out": "b1"},
          {"op": "linear", "layer": "fc", "in": "b1", "out": "logits"}
        ]
      }"#,
        )
        .unwrap(),
    )
    .unwrap();

    let mut rng = Rng::new(3);
    let mk = |rows: usize, cols: usize, rng: &mut Rng| -> (Mat, Vec<Scheme>, Vec<f32>) {
        let w = Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 0.4));
        let schemes: Vec<Scheme> = (0..rows)
            .map(|r| {
                if r * 100 < rows * 65 {
                    Scheme::PotW4A4
                } else if r * 100 < rows * 95 {
                    Scheme::FixedW4A4
                } else {
                    Scheme::FixedW8A4
                }
            })
            .collect();
        let alpha: Vec<f32> = (0..rows).map(|r| quant::default_alpha(w.row(r))).collect();
        (w, schemes, alpha)
    };
    let (wc, sc, ac) = mk(64, 288, &mut rng);
    let (wf, sf, af) = mk(10, 64, &mut rng);
    let layers = vec![
        layer("c1", "conv", (64, 32, 3, 3), 1, 1, wc, sc, ac),
        layer("fc", "linear", (10, 64, 1, 1), 0, 0, wf, sf, af),
    ];
    (manifest, ModelWeights { layers })
}

fn bench_executor(
    b: &mut Bench,
    name: &str,
    exec: &mut Executor,
    shape: (usize, usize, usize, usize),
) {
    let (n, c, h, w) = shape;
    let mut rng = Rng::new(5);
    let input: Vec<f32> = (0..n * c * h * w).map(|_| rng.uniform(0.0, 1.0)).collect();
    b.case_ops(name, Some(n as f64), || {
        let mut x = Tensor4::zeros(n, c, h, w);
        x.data.copy_from_slice(&input);
        black_box(exec.infer(x).unwrap());
    });
}

fn main() {
    let mut b = Bench::new("runtime");

    let seq_rt = Runtime::sequential();
    let par_rt = Runtime::new(ParallelConfig::default());
    println!("runtime: {} thread(s) in parallel config", par_rt.threads());

    let (manifest, weights) = synthetic_model();
    let shape = (4usize, 32usize, 16usize, 16usize);
    let mut seq = seq_rt.executor(manifest.clone(), weights.clone()).unwrap();
    let mut par = par_rt.executor(manifest, weights).unwrap();
    bench_executor(&mut b, "synthetic_seq", &mut seq, shape);
    bench_executor(&mut b, "synthetic_par", &mut par, shape);

    // the shipped model, when artifacts are present
    let dir = rmsmp::runtime::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("bench runtime/model_*: skipped (run `make artifacts`)");
        return;
    }
    let manifest = rmsmp::model::Manifest::load(&dir.join("manifest.json")).unwrap();
    let weights = ModelWeights::load(&dir.join("weights.bin")).unwrap();
    let s = manifest.input_shape.clone();
    let shape = (s[0], s[1], s[2], s[3]);
    let mut seq = seq_rt.executor(manifest.clone(), weights.clone()).unwrap();
    let mut par = par_rt.executor(manifest, weights).unwrap();
    bench_executor(&mut b, "model_seq", &mut seq, shape);
    bench_executor(&mut b, "model_par", &mut par, shape);
}
