//! Runtime benchmarks: PJRT artifact execution (the float reference path)
//! vs the integer executor on the same model — the L3 "two backends"
//! comparison, plus HLO compile time.
//!
//! Run after `make artifacts`: `cargo bench --bench bench_runtime`

use std::hint::black_box;

use rmsmp::model::{Executor, Manifest, ModelWeights};
use rmsmp::quant::tensor::Tensor4;
use rmsmp::runtime::Runtime;
use rmsmp::util::bench::Bench;
use rmsmp::util::rng::Rng;

fn main() {
    let dir = rmsmp::runtime::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("bench runtime: skipped (run `make artifacts`)");
        return;
    }
    let mut b = Bench::new("runtime");
    let manifest = Manifest::load(&dir.join("manifest.json")).unwrap();
    let weights = ModelWeights::load(&dir.join("weights.bin")).unwrap();
    let shape = manifest.input_shape.clone();
    let (n, c, h, w) = (shape[0], shape[1], shape[2], shape[3]);
    let imgs_per_iter = n as f64;

    // compile time (fresh runtime each iteration measures parse+compile)
    let t0 = std::time::Instant::now();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(&dir.join("model.hlo.txt")).unwrap();
    println!("runtime/compile_model_hlo: {:.1} ms (once)", t0.elapsed().as_secs_f64() * 1e3);

    let mut rng = Rng::new(5);
    let input: Vec<f32> = (0..n * c * h * w).map(|_| rng.uniform(0.0, 1.0)).collect();
    b.case_ops("pjrt_execute_batch", Some(imgs_per_iter), || {
        black_box(exe.run_f32(&[(black_box(&input), &shape)]).unwrap());
    });

    let mut exec = Executor::new(manifest, weights).unwrap();
    b.case_ops("integer_execute_batch", Some(imgs_per_iter), || {
        let mut x = Tensor4::zeros(n, c, h, w);
        x.data.copy_from_slice(&input);
        black_box(exec.infer(x).unwrap());
    });

    let gemm_exe = rt.load(&dir.join("gemm.hlo.txt")).unwrap();
    let (gb, gr, gc) = (8usize, 64usize, 576usize);
    let x: Vec<f32> = (0..gb * gc).map(|_| rng.uniform(0.0, 1.0)).collect();
    let wmat: Vec<f32> = rng.normal_vec(gr * gc, 0.4);
    let alpha = vec![1.0f32; gr];
    let scheme: Vec<i32> = (0..gr as i32).map(|r| r % 3).collect();
    b.case_ops("pjrt_pallas_gemm", Some((gb * gr * gc) as f64), || {
        use rmsmp::runtime::ArtifactInput as A;
        black_box(
            gemm_exe
                .run_mixed(&[
                    A::F32(&x, &[gb, gc]),
                    A::F32(&wmat, &[gr, gc]),
                    A::F32(&alpha, &[gr]),
                    A::I32(&scheme, &[gr]),
                ])
                .unwrap(),
        );
    });
}
