"""AOT entry point: build, quantize, and export the inference artifacts.

Usage (from python/):

    python -m compile.aot --out ../artifacts [--model resnet18]
                          [--ratio 65:30:5] [--train-steps 0] [--size 32]

Emits into the output directory:

    model.hlo.txt      quantized folded forward (Pallas kernels lowered in)
    gemm.hlo.txt       standalone row-wise mixed GEMM kernel (microbench)
    weights.bin        folded weights + schemes + alphas (Rust integer path)
    manifest.json      graph program + layer table + config
    model.rmsa         packed quantized planes (Rust zero-copy mmap path)
    testvec/*.json     cross-language quantizer test vectors
    parity.json        input/output pair for runtime parity checks

Python never runs at serving time; the Rust binary consumes these files.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import data, export, train
from .kernels import ref, rowwise_gemm
from .models import make, module_for
from . import testvec as testvec_mod


def build_model(args):
    cfg = make(args.model, num_classes=args.classes)
    model = module_for(cfg)
    params, qstates = model.init(jax.random.PRNGKey(args.seed), cfg)
    if args.train_steps > 0:
        n = max(args.train_steps * args.batch, 256)
        tr = data.image_dataset(args.classes, n=n, size=args.size, seed=args.seed)
        te = data.image_dataset(args.classes, n=256, size=args.size,
                                seed=args.seed, split="test")
        tcfg = train.TrainConfig(epochs=1, batch_size=args.batch,
                                 ratio=tuple(args.ratio), seed=args.seed)
        res = train.train(cfg, tr, te, tcfg, quant=True, init_params=params)
        params = res.params
        print(f"  trained {args.train_steps} steps, eval acc {res.eval_acc:.3f}")
    return cfg, params


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--model", default="resnet18",
                    choices=["resnet18", "resnet50", "mobilenetv2"])
    ap.add_argument("--ratio", default="65:30:5")
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--size", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--train-steps", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    args.ratio = [int(v) for v in args.ratio.split(":")]
    assert sum(args.ratio) == 100

    os.makedirs(args.out, exist_ok=True)
    print(f"[aot] building {args.model} ratio={args.ratio}")
    cfg, params = build_model(args)

    # 1. fold + assign + calibrate
    lys, prog = export.fold_model(params, cfg)
    export.assign_folded(lys, tuple(args.ratio))
    probe, _ = data.image_dataset(args.classes, n=16, size=args.size,
                                  seed=args.seed)
    export.calibrate_folded(lys, prog, probe)

    # 2. HLO artifacts
    in_shape = (args.batch, 3, args.size, args.size)
    spec = jax.ShapeDtypeStruct(in_shape, jnp.float32)
    fn = lambda x: (export.infer_folded(lys, prog, x, use_pallas=True),)
    hlo = export.to_hlo_text(fn, spec)
    with open(os.path.join(args.out, "model.hlo.txt"), "w") as f:
        f.write(hlo)
    print(f"[aot] model.hlo.txt ({len(hlo)} chars)")

    gb, gr, gc = 8, 64, 576
    gemm_fn = lambda x, w, a, s: (rowwise_gemm.rowwise_mixed_gemm(
        x, w, a, s, act_alpha=1.0),)
    gemm_hlo = export.to_hlo_text(
        gemm_fn,
        jax.ShapeDtypeStruct((gb, gc), jnp.float32),
        jax.ShapeDtypeStruct((gr, gc), jnp.float32),
        jax.ShapeDtypeStruct((gr,), jnp.float32),
        jax.ShapeDtypeStruct((gr,), jnp.int32),
    )
    with open(os.path.join(args.out, "gemm.hlo.txt"), "w") as f:
        f.write(gemm_hlo)
    print(f"[aot] gemm.hlo.txt ({len(gemm_hlo)} chars) shape=({gb},{gr},{gc})")

    # 3. weights + manifest
    export.write_weights_bin(os.path.join(args.out, "weights.bin"), lys)
    manifest = export.manifest_dict(cfg, lys, prog, args.ratio, in_shape)
    manifest["gemm_shape"] = [gb, gr, gc]
    manifest_json = json.dumps(manifest, indent=1)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        f.write(manifest_json)
    rmsa_path = os.path.join(args.out, "model.rmsa")
    export.write_rmsa(rmsa_path, lys, manifest_json)
    print(f"[aot] weights.bin + manifest.json + model.rmsa "
          f"({len(lys)} layers, {os.path.getsize(rmsa_path)} B packed)")

    # 4. parity vector: quantized forward on a fixed input
    x0 = jnp.asarray(probe[: args.batch])
    y0 = export.infer_folded(lys, prog, x0, use_pallas=False)
    with open(os.path.join(args.out, "parity.json"), "w") as f:
        json.dump({
            "input": np.asarray(x0).reshape(-1).tolist(),
            "input_shape": list(x0.shape),
            "logits": np.asarray(y0).reshape(-1).tolist(),
            "logits_shape": list(y0.shape),
        }, f)
    print("[aot] parity.json")

    # 5. cross-language quantizer test vectors
    tv_dir = os.path.join(args.out, "testvec")
    testvec_mod.write_all(tv_dir)
    print(f"[aot] testvec/ -> {tv_dir}")


if __name__ == "__main__":
    main()
