"""RMSMP build-time package: L1 Pallas kernels, L2 JAX models/QAT, AOT export.

Never imported at runtime — the Rust binary consumes only the artifacts this
package emits (``make artifacts``).
"""
