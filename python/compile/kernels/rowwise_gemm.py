"""L1 Pallas kernel: row-wise mixed-scheme quantized GEMM.

Computes ``y = Qa(x) @ Qw(w)^T`` where Qa is the 4-bit Fixed activation
quantizer and Qw quantizes each *row* of w with that row's scheme
(PoT-W4A4 / Fixed-W4A4 / Fixed-W8A4) — the paper's heterogeneous-GEMM-core
computation as a single TPU kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation)
----------------------------------------------------
The FPGA version routes each row class to a different PE array (DSP-based
multipliers for Fixed, LUT shift-add for PoT). On TPU there is one MXU, so
instead of heterogeneous *compute*, we use heterogeneous *dequantization*:
the weight tile is fake-quantized per row class in the VPU (element-wise,
cheap) and a single dense MXU matmul consumes the result. The BlockSpec
below expresses the paper's tiling: weights stream HBM→VMEM in
(block_n x block_k) tiles with per-row metadata riding along the n axis,
and the activation tile is reused across all n tiles (the paper's "layer-
wise uniformality" means every tile has the same scheme mix, so tile cost
is uniform and the schedule is static).

VMEM budget per grid step (block_m=block_n=128, block_k=256, f32):
  x tile 128x256 (128 KiB) + w tile 128x256 (128 KiB) + 3 dequant temps
  (384 KiB) + out tile 128x128 (64 KiB) ≈ 0.7 MiB — comfortably inside the
  16 MiB VMEM of a TPU core; see EXPERIMENTS.md §Perf for the sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .quantizers import INTERPRET, _block, _clip, _fixed_body, _pad_to, _pot_body


def _mixed_gemm_kernel(
    x_ref, w_ref, alpha_ref, scheme_ref, o_ref, acc_ref, *, act_alpha: float,
    act_bits: int, nk: int
):
    """One (i, j, k) grid step: acc += Qa(x[i,k]) @ Qw(w[j,k])^T.

    Grid is (m_tiles, n_tiles, k_tiles) with k innermost; the f32 scratch
    accumulator lives in VMEM across the k loop and is flushed to o_ref at
    k == nk-1 (the standard Pallas matmul accumulation pattern).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Activation fake quant (4-bit unsigned Fixed), VPU element-wise.
    n_a = float(2**act_bits - 1)
    xq = act_alpha * jnp.round(jnp.clip(x_ref[...] / act_alpha, 0.0, 1.0) * n_a) / n_a

    # Row-wise mixed-scheme weight dequant.
    a = alpha_ref[...][:, None]
    s = scheme_ref[...][:, None]
    t = _clip(w_ref[...], a)
    wq = a * jnp.where(
        s == ref.POT_W4A4,
        _pot_body(t, 4),
        jnp.where(s == ref.FIXED_W4A4, _fixed_body(t, 4), _fixed_body(t, 8)),
    )

    acc_ref[...] += jax.lax.dot_general(
        xq, wq, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def rowwise_mixed_gemm(
    x, w, alpha, scheme, act_alpha, act_bits: int = 4,
    block_m: int = 128, block_n: int = 128, block_k: int = 256,
):
    """Pallas row-wise mixed-scheme quantized GEMM; oracle: ``ref.rowwise_mixed_gemm``.

    Args:
      x:        (batch, cols) f32 activations.
      w:        (rows, cols) f32 weights (row-major, one scheme per row).
      alpha:    (rows,) per-row weight scale.
      scheme:   (rows,) int32 scheme codes.
      act_alpha: scalar activation clip.
      act_bits: activation bit-width (4 in the paper's W*A4 configs).

    Returns: (batch, rows) f32.
    """
    batch, cols = x.shape
    rows, cols_w = w.shape
    assert cols == cols_w, f"x cols {cols} != w cols {cols_w}"
    assert alpha.shape == (rows,) and scheme.shape == (rows,)

    bm = _block(batch, block_m)
    bn = _block(rows, block_n)
    bk = _block(cols, block_k)

    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bn, 0), bk, 1)
    ap = _pad_to(alpha, bn, 0, value=1.0)
    sp = _pad_to(scheme.astype(jnp.int32), bn, 0, value=ref.FIXED_W4A4)

    nm, nn, nk = xp.shape[0] // bm, wp.shape[0] // bn, xp.shape[1] // bk
    out = pl.pallas_call(
        functools.partial(
            _mixed_gemm_kernel, act_alpha=float(act_alpha), act_bits=act_bits, nk=nk
        ),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[0]), jnp.float32),
        scratch_shapes=[_vmem_scratch(bm, bn)],
        interpret=INTERPRET,
    )(xp, wp, ap, sp)
    return out[:batch, :rows]


def _vmem_scratch(bm: int, bn: int):
    """f32 VMEM scratch accumulator (interpret mode executes it as ndarray)."""
    from jax.experimental.pallas import tpu as pltpu  # local: TPU namespace

    return pltpu.VMEM((bm, bn), jnp.float32)


def vmem_bytes(block_m: int, block_n: int, block_k: int) -> int:
    """Static VMEM footprint estimate for one grid step (bytes, f32).

    Used by the perf harness and DESIGN.md to pick block shapes: x tile +
    w tile + 3 dequant temps + accumulator + out tile.
    """
    f = 4
    x_t = block_m * block_k * f
    w_t = block_n * block_k * f
    temps = 3 * block_n * block_k * f
    acc = block_m * block_n * f
    out = block_m * block_n * f
    return x_t + w_t + temps + acc + out


def mxu_utilization_estimate(
    batch: int, rows: int, cols: int, block_m: int = 128, block_n: int = 128,
    block_k: int = 256,
) -> float:
    """Estimated MXU utilization: useful MACs / (padded tiles x tile MACs).

    The MXU processes 128x128 tiles; padding waste is the only structural
    inefficiency of this kernel (dequant runs on the VPU in parallel).
    """
    import math

    nm = math.ceil(batch / block_m)
    nn = math.ceil(rows / block_n)
    nk = math.ceil(cols / block_k)
    useful = batch * rows * cols
    padded = nm * nn * nk * block_m * block_n * block_k
    return useful / padded
