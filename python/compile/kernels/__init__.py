"""L1: Pallas kernels (quantizers, row-wise mixed GEMM) + pure-jnp oracles."""
