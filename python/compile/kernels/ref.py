"""Pure-jnp reference oracles for the RMSMP quantizers and GEMMs.

These implement the paper's equations directly and serve as the correctness
ground truth for (a) the Pallas kernels in this package and (b) the bit-exact
Rust implementations in ``rust/src/quant`` / ``rust/src/gemm`` (via shared
test vectors emitted by ``python -m compile.testvec``).

Conventions
-----------
* All quantizers are symmetric with a per-row scaling factor ``alpha``
  (the paper quantizes per filter / per row of the weight matrix).
* ``m`` is the bit-width *including* the sign bit, matching Eq. (1)/(4).
* Activations are always Fixed (the paper quantizes activations to Fixed so
  a PoT weight x Fixed activation multiply becomes a bit shift).

Scheme codes (shared with Rust, ``rust/src/quant/scheme.rs``)::

    0 = PoT-W4A4     1 = Fixed-W4A4     2 = Fixed-W8A4
"""

from __future__ import annotations

import jax.numpy as jnp

# Scheme codes shared across L1/L2/L3. Codes 0-2 are the RMSMP classes the
# hardware kernel implements; code 3 (APoT) exists for the Table 1/6
# baseline schemes and is only used on the training/reference path.
POT_W4A4 = 0
FIXED_W4A4 = 1
FIXED_W8A4 = 2
APOT_W4A4 = 3

SCHEME_NAMES = {POT_W4A4: "PoT-W4A4", FIXED_W4A4: "Fixed-W4A4",
                FIXED_W8A4: "Fixed-W8A4", APOT_W4A4: "APoT-W4A4"}


# ---------------------------------------------------------------------------
# Eq. (3): clip w to [-1, 1] in units of alpha.
# ---------------------------------------------------------------------------
def clip_scale(w, alpha):
    """``⌈w, α⌋`` from Eq. (3): w/alpha clipped into [-1, 1]."""
    return jnp.clip(w / alpha, -1.0, 1.0)


# ---------------------------------------------------------------------------
# Eq. (1)-(2): Fixed-point quantizer.
# ---------------------------------------------------------------------------
def fixed_levels(m: int) -> jnp.ndarray:
    """Positive quantization levels of m-bit Fixed (Eq. 1), without alpha."""
    n = 2 ** (m - 1) - 1
    return jnp.arange(0, n + 1, dtype=jnp.float32) / n


def fixed_quant(w, alpha, m: int):
    """Project w onto Q^Fixed(m, alpha) (Eq. 1-3).

    Symmetric m-bit fixed point: the quantized value is
    ``alpha * round(clip(w/alpha) * (2^{m-1}-1)) / (2^{m-1}-1)``.

    This is the standard simplification of Eq. (2): the h(.)/h^{-1}(.)
    affine shuffle with a (2^m - 1)-level rounding grid over [0, 1] is
    exactly a (2^{m-1} - 1)-step symmetric grid over [-1, 1] once the
    half-step offset cancels. We use the symmetric form because it is what
    integer hardware (and our Rust GEMM cores) executes: an i(m) weight
    code in [-(2^{m-1}-1), 2^{m-1}-1].
    """
    n = float(2 ** (m - 1) - 1)
    t = clip_scale(w, alpha)
    return alpha * jnp.round(t * n) / n


def fixed_quant_code(w, alpha, m: int):
    """Integer weight code in [-(2^{m-1}-1), +(2^{m-1}-1)] (what hardware stores)."""
    n = float(2 ** (m - 1) - 1)
    return jnp.round(clip_scale(w, alpha) * n).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Eq. (4)-(5): Power-of-Two quantizer.
# ---------------------------------------------------------------------------
def pot_levels(m: int) -> jnp.ndarray:
    """Positive quantization levels of m-bit PoT (Eq. 4), without alpha.

    {0} ∪ {2^-(2^{m-1}-2), ..., 2^-1, 2^0}; one bit is the sign, so there
    are 2^{m-1}-1 nonzero exponent levels plus zero.
    """
    k = 2 ** (m - 1) - 2  # smallest exponent magnitude
    exps = jnp.arange(-k, 1, dtype=jnp.float32)  # -k .. 0
    return jnp.concatenate([jnp.zeros((1,), jnp.float32), 2.0**exps])


def pot_quant(w, alpha, m: int):
    """Project w onto Q^PoT(m, alpha) (Eq. 4-5).

    Magnitudes round to the nearest power of two in log2 space; magnitudes
    below the midpoint of the smallest level quantize to 0. Matches Eq. (5)
    with the symmetric (sign + exponent) reading used by the hardware.
    """
    k = 2 ** (m - 1) - 2
    t = clip_scale(w, alpha)
    mag = jnp.abs(t)
    sign = jnp.sign(t)
    # round(log2 mag) with mag clamped into representable range.
    safe = jnp.maximum(mag, 2.0 ** (-k - 4))
    e = jnp.clip(jnp.round(jnp.log2(safe)), -k, 0)
    q = 2.0**e
    # Zero threshold: below half of the smallest nonzero level -> 0.
    # (Eq. 5 uses 2^(-2^m + 1) in its h-domain formulation; in the
    # symmetric domain the cut sits between 0's basin and 2^-k. We use
    # half the smallest level, which is what a shift-only datapath
    # implements.)
    zero = mag < (2.0 ** (-k)) / 2.0
    return alpha * sign * jnp.where(zero, 0.0, q)


def pot_quant_code(w, alpha, m: int):
    """(sign, exponent) code: sign in {-1,0,1}, exponent in [-k, 0].

    Hardware stores sign + unsigned shift amount ``s = -e`` in m-1 bits,
    with a reserved code for 0.
    """
    k = 2 ** (m - 1) - 2
    t = clip_scale(w, alpha)
    mag = jnp.abs(t)
    sign = jnp.sign(t).astype(jnp.int32)
    safe = jnp.maximum(mag, 2.0 ** (-k - 4))
    e = jnp.clip(jnp.round(jnp.log2(safe)), -k, 0).astype(jnp.int32)
    zero = mag < (2.0 ** (-k)) / 2.0
    sign = jnp.where(zero, 0, sign)
    e = jnp.where(zero, 0, e)
    return sign, e


# ---------------------------------------------------------------------------
# APoT (Li et al. 2020) — baseline scheme for Table 1 / Table 6 rows.
# ---------------------------------------------------------------------------
def apot_levels(m: int) -> jnp.ndarray:
    """Positive APoT levels for m bits (sum of two PoT terms), max-normalized.

    Follows APoT's 4-bit weight construction: two additive terms, each from
    a small PoT set, giving denser levels than PoT at the tails. For m = 4:
    p0 in {0, 2^0, 2^-2, 2^-4}, p1 in {0, 2^-1, 2^-3, 2^-5};
    levels = sorted unique (p0 + p1), 8 nonnegative levels after dedup-trim.
    Other m fall back to a two-group generalization.
    """
    import numpy as np  # static table: computed in numpy so it traces as a constant

    if m <= 2:
        return jnp.asarray([0.0, 1.0], jnp.float32)
    if m == 4:
        # sign + 3 magnitude bits = 2-bit term + 1-bit term (k = 2):
        # p0 in {0, 2^0, 2^-2, 2^-4}, p1 in {0, 2^-1} -> 8 distinct sums.
        p0 = np.asarray([0.0, 1.0, 2.0**-2, 2.0**-4], np.float32)
        p1 = np.asarray([0.0, 2.0**-1], np.float32)
    else:
        # generic k = 2 split of the m-1 magnitude bits into ceil/floor halves
        b0 = (m - 1 + 1) // 2
        b1 = (m - 1) - b0
        p0 = np.concatenate(
            [np.zeros((1,)), 2.0 ** -np.arange(0.0, 2.0 * (2**b0 - 1), 2.0)]
        ).astype(np.float32)
        p1 = np.concatenate(
            [np.zeros((1,)), 2.0 ** -np.arange(1.0, 2.0 * (2**b1 - 1) + 1, 2.0)]
        ).astype(np.float32)
    lv = np.unique((p0[:, None] + p1[None, :]).reshape(-1))
    return jnp.asarray(lv / lv.max(), jnp.float32)


def project_levels(w, alpha, levels):
    """Project w/alpha onto the nearest of ±levels (levels are nonnegative)."""
    t = clip_scale(w, alpha)
    mag = jnp.abs(t)[..., None]
    idx = jnp.argmin(jnp.abs(mag - levels), axis=-1)
    q = levels[idx]
    return alpha * jnp.sign(t) * q


def apot_quant(w, alpha, m: int):
    """Project w onto the APoT grid (baseline for Table 1/6)."""
    return project_levels(w, alpha, apot_levels(m))


# ---------------------------------------------------------------------------
# Activation quantizer: unsigned Fixed (post-ReLU) or signed Fixed.
# ---------------------------------------------------------------------------
def act_quant(x, alpha, m: int, signed: bool = False):
    """Quantize activations to m-bit Fixed with clipping threshold alpha.

    Post-ReLU activations are unsigned: levels {0, ..., 2^m - 1} / (2^m - 1).
    The signed variant mirrors fixed_quant (used pre-GELU in the BERT path).
    """
    if signed:
        return fixed_quant(x, alpha, m)
    n = float(2**m - 1)
    t = jnp.clip(x / alpha, 0.0, 1.0)
    return alpha * jnp.round(t * n) / n


def act_quant_code(x, alpha, m: int):
    """Unsigned activation code in [0, 2^m - 1]."""
    n = float(2**m - 1)
    return jnp.round(jnp.clip(x / alpha, 0.0, 1.0) * n).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Row-wise mixed-scheme quantization (the RMSMP weight projector).
# ---------------------------------------------------------------------------
def rowwise_quant(w, alpha, scheme):
    """Quantize each row of ``w`` per its scheme code.

    Args:
      w:       (rows, cols) float32 weight matrix.
      alpha:   (rows,) per-row scaling factors.
      scheme:  (rows,) int32 scheme codes (0=PoT4, 1=Fixed4, 2=Fixed8).

    Returns: (rows, cols) fake-quantized float32 weights.
    """
    a = alpha[:, None]
    qp = pot_quant(w, a, 4)
    qf4 = fixed_quant(w, a, 4)
    qf8 = fixed_quant(w, a, 8)
    qa4 = apot_quant(w, a, 4)
    s = scheme[:, None]
    return jnp.where(
        s == POT_W4A4, qp,
        jnp.where(s == FIXED_W4A4, qf4, jnp.where(s == FIXED_W8A4, qf8, qa4)))


def rowwise_mixed_gemm(x, w, alpha, scheme, act_alpha, act_bits: int = 4):
    """Reference for the row-wise mixed-scheme quantized GEMM.

    ``y[b, r] = sum_c act_quant(x)[b, c] * rowwise_quant(w)[r, c]``

    i.e. a (batch, cols) x (rows, cols)^T matmul where each output row uses
    its own weight quantizer — the computation the paper's three
    heterogeneous GEMM cores execute on the FPGA, and the oracle for the L1
    Pallas kernel.
    """
    xq = act_quant(x, act_alpha, act_bits)
    wq = rowwise_quant(w, alpha, scheme)
    return xq @ wq.T


def default_alpha(w, axis=None):
    """Per-row scaling factor: max |w| along the row (the paper clips at the
    weight max; learned alphas are an orthogonal refinement)."""
    if axis is None:
        return jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)
    a = jnp.max(jnp.abs(w), axis=axis)
    return jnp.maximum(a, 1e-8)
