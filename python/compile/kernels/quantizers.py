"""L1 Pallas fake-quantization kernels.

Row-tiled quantizer kernels used by the L2 model's forward pass. Each kernel
processes a (block_rows, block_cols) VMEM tile of the weight matrix plus the
per-row metadata (alpha, scheme) for that row block, and writes the
fake-quantized tile.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper's
per-row scheme dispatch onto heterogeneous FPGA PE arrays becomes a
branchless per-row select inside one kernel — on TPU all three dequant paths
are cheap VPU element-wise ops, and the select keeps the tile dense for the
MXU consumer downstream.

All kernels run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); on a real TPU the same code lowers to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

INTERPRET = True  # CPU PJRT: interpret mode is mandatory (see module doc).


def _pad_to(x, mult, axis, value=0.0):
    """Pad ``x`` along ``axis`` up to a multiple of ``mult``."""
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


def _block(n: int, pref: int) -> int:
    """Pick a block size: the preferred tile unless the dim is smaller."""
    return min(pref, max(n, 1))


# ---------------------------------------------------------------------------
# Element-wise quantizer bodies (shared by the kernels; identical math to
# ref.py so kernel-vs-oracle tests are exact).
# ---------------------------------------------------------------------------
def _fixed_body(t, m: int):
    n = float(2 ** (m - 1) - 1)
    return jnp.round(t * n) / n


def _pot_body(t, m: int):
    k = 2 ** (m - 1) - 2
    mag = jnp.abs(t)
    sign = jnp.sign(t)
    safe = jnp.maximum(mag, 2.0 ** (-k - 4))
    e = jnp.clip(jnp.round(jnp.log2(safe)), -k, 0)
    q = 2.0**e
    zero = mag < (2.0 ** (-k)) / 2.0
    return sign * jnp.where(zero, 0.0, q)


def _clip(w, alpha):
    return jnp.clip(w / alpha, -1.0, 1.0)


# ---------------------------------------------------------------------------
# Kernels.
# ---------------------------------------------------------------------------
def _fixed_kernel(w_ref, alpha_ref, o_ref, *, m: int):
    a = alpha_ref[...][:, None]
    t = _clip(w_ref[...], a)
    o_ref[...] = a * _fixed_body(t, m)


def _pot_kernel(w_ref, alpha_ref, o_ref, *, m: int):
    a = alpha_ref[...][:, None]
    t = _clip(w_ref[...], a)
    o_ref[...] = a * _pot_body(t, m)


def _rowwise_kernel(w_ref, alpha_ref, scheme_ref, o_ref):
    """Branchless row-wise mixed-scheme fake quant (PoT4 / Fixed4 / Fixed8)."""
    a = alpha_ref[...][:, None]
    s = scheme_ref[...][:, None]
    t = _clip(w_ref[...], a)
    qp = _pot_body(t, 4)
    qf4 = _fixed_body(t, 4)
    qf8 = _fixed_body(t, 8)
    o_ref[...] = a * jnp.where(
        s == ref.POT_W4A4, qp, jnp.where(s == ref.FIXED_W4A4, qf4, qf8)
    )


def _act_kernel(x_ref, o_ref, *, m: int, alpha: float):
    n = float(2**m - 1)
    t = jnp.clip(x_ref[...] / alpha, 0.0, 1.0)
    o_ref[...] = alpha * jnp.round(t * n) / n


# ---------------------------------------------------------------------------
# Public entry points (pad → pallas_call → slice).
# ---------------------------------------------------------------------------
def _rowwise_call(kernel, w, alpha, extra, br: int = 128, bc: int = 256):
    rows, cols = w.shape
    br = _block(rows, br)
    bc = _block(cols, bc)
    wp = _pad_to(_pad_to(w, br, 0), bc, 1)
    ap = _pad_to(alpha, br, 0, value=1.0)
    args = [wp, ap]
    specs = [
        pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        pl.BlockSpec((br,), lambda i, j: (i,)),
    ]
    for e in extra:
        args.append(_pad_to(e, br, 0))
        specs.append(pl.BlockSpec((br,), lambda i, j: (i,)))
    grid = (wp.shape[0] // br, wp.shape[1] // bc)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=specs,
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(wp.shape, jnp.float32),
        interpret=INTERPRET,
    )(*args)
    return out[:rows, :cols]


def fixed_quant(w, alpha, m: int):
    """Pallas row-tiled Fixed fake quant; matches ``ref.fixed_quant``."""
    return _rowwise_call(functools.partial(_fixed_kernel, m=m), w, alpha, ())


def pot_quant(w, alpha, m: int):
    """Pallas row-tiled PoT fake quant; matches ``ref.pot_quant``."""
    return _rowwise_call(functools.partial(_pot_kernel, m=m), w, alpha, ())


def rowwise_quant(w, alpha, scheme):
    """Pallas row-wise mixed-scheme fake quant; matches ``ref.rowwise_quant``."""
    return _rowwise_call(_rowwise_kernel, w, alpha, (scheme.astype(jnp.int32),))


def act_quant(x, alpha: float, m: int, bm: int = 128, bn: int = 256):
    """Pallas unsigned activation fake quant; matches ``ref.act_quant``."""
    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1]) if x.ndim != 2 else x
    r, c = x2.shape
    bm = _block(r, bm)
    bn = _block(c, bn)
    xp = _pad_to(_pad_to(x2, bm, 0), bn, 1)
    out = pl.pallas_call(
        functools.partial(_act_kernel, m=m, alpha=float(alpha)),
        grid=(xp.shape[0] // bm, xp.shape[1] // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        interpret=INTERPRET,
    )(xp)
    return out[:r, :c].reshape(orig_shape)
