"""Synthetic datasets (DESIGN.md §3 substitutions for CIFAR/ImageNet/GLUE).

Image tasks — ``synth{K}``: class-conditional images built from a per-class
low-frequency template + per-class texture frequency + noise; learnable by a
small CNN in a few hundred steps yet non-trivial (noise keeps Bayes accuracy
< 100%), so quantization-induced accuracy deltas are visible.

Text tasks — ``sst2-syn`` (2-class) / ``mnli-syn`` (3-class): token sequences
where the class is the majority vote of class-indicative token groups with
distractors, mimicking sentiment/NLI surface statistics.

Everything is deterministic in (seed, split).
"""

from __future__ import annotations

import numpy as np


def _class_templates(rng, num_classes: int, ch: int, size: int) -> np.ndarray:
    """Low-frequency per-class templates in [0, 1]."""
    base = rng.normal(size=(num_classes, ch, 4, 4)).astype(np.float32)
    # bilinear upsample 4x4 -> size x size
    t = np.zeros((num_classes, ch, size, size), np.float32)
    xs = np.linspace(0, 3, size)
    x0 = np.floor(xs).astype(int).clip(0, 2)
    fx = xs - x0
    for i in range(num_classes):
        for c in range(ch):
            g = base[i, c]
            rows = (g[x0, :] * (1 - fx)[:, None] + g[x0 + 1, :] * fx[:, None])
            t[i, c] = rows[:, x0] * (1 - fx)[None, :] + rows[:, x0 + 1] * fx[None, :]
    t = (t - t.min()) / (t.max() - t.min() + 1e-8)
    return t


def image_dataset(num_classes: int = 10, n: int = 2048, size: int = 32,
                  ch: int = 3, seed: int = 0, split: str = "train",
                  noise: float = 0.25):
    """Returns (images (n, ch, size, size) f32 in [0,1), labels (n,) int32)."""
    rng = np.random.default_rng(seed * 7919 + (0 if split == "train" else 104729))
    tpl_rng = np.random.default_rng(seed)  # templates shared across splits
    templates = _class_templates(tpl_rng, num_classes, ch, size)
    freqs = tpl_rng.uniform(1.0, float(max(2, size // 4)), size=(num_classes,))
    labels = rng.integers(0, num_classes, size=n).astype(np.int32)
    yy, xx = np.meshgrid(np.arange(size), np.arange(size), indexing="ij")
    imgs = np.empty((n, ch, size, size), np.float32)
    for i in range(n):
        c = labels[i]
        phase = rng.uniform(0, 2 * np.pi)
        tex = 0.5 + 0.5 * np.sin(2 * np.pi * freqs[c] * (xx + yy) / size + phase)
        img = 0.55 * templates[c] + 0.2 * tex[None] + noise * rng.random((ch, size, size))
        imgs[i] = img
    imgs = np.clip(imgs / imgs.max(axis=(1, 2, 3), keepdims=True), 0.0, 0.999)
    return imgs.astype(np.float32), labels


def text_dataset(task: str = "sst2-syn", n: int = 2048, seq: int = 32,
                 vocab: int = 256, seed: int = 0, split: str = "train"):
    """Returns (tokens (n, seq) int32, labels (n,) int32, num_classes)."""
    num_classes = 2 if task.startswith("sst2") else 3
    rng = np.random.default_rng(seed * 6101 + (0 if split == "train" else 15485863))
    grp_rng = np.random.default_rng(seed + 17)
    # Disjoint class-indicative token groups + shared distractor pool.
    perm = grp_rng.permutation(vocab)
    g = (vocab // 2) // num_classes
    groups = [perm[i * g:(i + 1) * g] for i in range(num_classes)]
    distractors = perm[num_classes * g:]
    tokens = np.empty((n, seq), np.int64)
    labels = rng.integers(0, num_classes, size=n).astype(np.int32)
    for i in range(n):
        c = labels[i]
        k = rng.integers(seq // 4, seq // 2)  # indicative tokens
        row = rng.choice(distractors, size=seq)
        pos = rng.choice(seq, size=k, replace=False)
        row[pos] = rng.choice(groups[c], size=k)
        # inject a few tokens of a wrong class as noise
        other = (c + 1) % num_classes
        npos = rng.choice(seq, size=max(1, seq // 10), replace=False)
        row[npos] = rng.choice(groups[other], size=len(npos))
        tokens[i] = row
    return tokens.astype(np.int32), labels, num_classes


def batches(x, y, batch_size: int, seed: int = 0, epochs: int = 1):
    """Shuffled minibatch iterator (drops the ragged tail)."""
    n = x.shape[0]
    for e in range(epochs):
        rng = np.random.default_rng(seed + e)
        idx = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            j = idx[i:i + batch_size]
            yield x[j], y[j]
