"""L2 quantized layers: STE fake-quant wrappers over the L1 kernels.

Functional layer library (no flax dependency): each layer is a pair of
``init(rng, ...) -> params`` and ``apply(params, x, qstate) -> y`` functions
operating on plain dicts, so the whole model is a pytree and AOT lowering is
trivial.

Quantization state (``qstate``) per quantized layer::

    {"scheme": (rows,) int32,   # 0=PoT4 / 1=Fixed4 / 2=Fixed8 per row/filter
     "w_alpha": (rows,) f32,    # per-row weight clip (refreshed from weights)
     "a_alpha": () f32}         # activation clip

All qstate leaves are arrays so the whole dict is jit-traceable; the
activation bit-width is static (A4 throughout the paper) and passed as the
``act_bits`` argument where it matters.

During QAT the forward uses the pure-jnp oracles (fast on CPU); the AOT
inference path (aot.py) routes the same math through the Pallas kernels so
the shipped HLO contains the L1 kernel lowering. Both are covered by the
kernel-vs-ref tests, so the two paths are numerically interchangeable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref


def ste(q, w):
    """Straight-Through Estimator (Eq. 6): forward q, backward identity."""
    return w + jax.lax.stop_gradient(q - w)


def fake_quant_weight(w2d, qstate, use_pallas: bool = False):
    """Row-wise mixed-scheme fake quant of a (rows, cols) weight matrix."""
    alpha = qstate["w_alpha"]
    scheme = qstate["scheme"]
    if use_pallas:
        from .kernels import quantizers as qz

        q = qz.rowwise_quant(w2d, alpha, scheme)
    else:
        q = ref.rowwise_quant(w2d, alpha, scheme)
    return ste(q, w2d)


# When not None, fake_quant_act records per-layer input percentiles into
# this dict (keyed by id(qstate)) instead of quantizing — the activation-
# clip calibration pass (train._calibrate_act) runs one unjitted forward in
# this mode and maps the stats back to layer names.
_CALIB: dict | None = None


def fake_quant_act(x, qstate, use_pallas: bool = False, act_bits: int = 4,
                   signed: bool = False):
    """Fixed fake quant of activations (A4 in the paper).

    Unsigned for post-ReLU paths; ``signed=True`` for transformer
    activations (pre-GELU / residual streams)."""
    global _CALIB
    if _CALIB is not None:
        import numpy as np

        mag = float(np.percentile(np.abs(np.asarray(x)), 99.5))
        prev = _CALIB.get(id(qstate), 0.0)
        _CALIB[id(qstate)] = max(prev, mag)
        return x
    a = qstate["a_alpha"]
    m = act_bits
    if signed:
        return ste(ref.fixed_quant(x, a, m), x)
    if use_pallas:
        from .kernels import quantizers as qz

        q = qz.act_quant(x, a, m)
    else:
        q = ref.act_quant(x, a, m)
    return ste(q, x)


def default_qstate(rows: int) -> dict:
    """All-rows Fixed-4 qstate; assignment.py rewrites ``scheme``."""
    return {
        "scheme": jnp.full((rows,), ref.FIXED_W4A4, jnp.int32),
        "w_alpha": jnp.ones((rows,), jnp.float32),
        "a_alpha": jnp.asarray(4.0, jnp.float32),
    }


def refresh_alpha(w2d, qstate) -> dict:
    """Recompute per-row weight clips from current weights (max |w| per row)."""
    return dict(qstate, w_alpha=ref.default_alpha(w2d, axis=1))


# ---------------------------------------------------------------------------
# Linear.
# ---------------------------------------------------------------------------
def linear_init(rng, in_dim: int, out_dim: int) -> dict:
    k = jnp.sqrt(1.0 / in_dim)
    w = jax.random.uniform(rng, (out_dim, in_dim), jnp.float32, -k, k)
    return {"w": w, "b": jnp.zeros((out_dim,), jnp.float32)}


def linear_apply(params, x, qstate=None, quant_in: bool = True,
                 use_pallas: bool = False):
    """y = Qa(x) @ Qw(w)^T + b ; unquantized when qstate is None."""
    w = params["w"]
    if qstate is not None:
        if quant_in:
            x = fake_quant_act(x, qstate, use_pallas)
        w = fake_quant_weight(w, qstate, use_pallas)
    return x @ w.T + params["b"]


# ---------------------------------------------------------------------------
# Conv2d (NCHW, OIHW weights). Rows of the weight matrix = output filters.
# ---------------------------------------------------------------------------
def conv_init(rng, in_ch: int, out_ch: int, k: int) -> dict:
    fan_in = in_ch * k * k
    std = jnp.sqrt(2.0 / fan_in)
    w = jax.random.normal(rng, (out_ch, in_ch, k, k), jnp.float32) * std
    return {"w": w}


def conv_apply(params, x, qstate=None, stride: int = 1, padding=None,
               quant_in: bool = True, use_pallas: bool = False,
               groups: int = 1):
    """Quantized conv: each output filter is one 'row' of the weight matrix.

    Padding is explicit and *symmetric* ((k-1)/2 on each side) rather than
    XLA's "SAME" (which pads asymmetrically for even inputs at stride 2):
    training, the folded export, and the Rust im2col executor must agree on
    alignment, and symmetric is what the hardware pipeline implements.
    """
    w = params["w"]
    if qstate is not None:
        if quant_in:
            x = fake_quant_act(x, qstate, use_pallas)
        oc = w.shape[0]
        w2d = w.reshape(oc, -1)
        w = fake_quant_weight(w2d, qstate, use_pallas).reshape(w.shape)
    if padding is None:
        p = (w.shape[-1] - 1) // 2
        padding = [(p, p), (p, p)]
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"), feature_group_count=groups,
    )


# ---------------------------------------------------------------------------
# BatchNorm (train: batch stats + running update; eval: running stats).
# ---------------------------------------------------------------------------
def bn_init(ch: int) -> dict:
    return {
        "gamma": jnp.ones((ch,), jnp.float32),
        "beta": jnp.zeros((ch,), jnp.float32),
        "mean": jnp.zeros((ch,), jnp.float32),
        "var": jnp.ones((ch,), jnp.float32),
    }


def bn_apply(params, x, train: bool, momentum: float = 0.9, eps: float = 1e-5):
    """Returns (y, new_params). x is NCHW (or (N, C) for 1-D)."""
    axes = (0, 2, 3) if x.ndim == 4 else (0,)
    shape = (1, -1, 1, 1) if x.ndim == 4 else (1, -1)
    if train:
        mean = jnp.mean(x, axis=axes)
        var = jnp.var(x, axis=axes)
        new = dict(
            params,
            mean=momentum * params["mean"] + (1 - momentum) * mean,
            var=momentum * params["var"] + (1 - momentum) * var,
        )
    else:
        mean, var, new = params["mean"], params["var"], params
    y = (x - mean.reshape(shape)) / jnp.sqrt(var.reshape(shape) + eps)
    return y * params["gamma"].reshape(shape) + params["beta"].reshape(shape), new


def bn_fold(conv_params: dict, bn_params: dict, eps: float = 1e-5) -> dict:
    """Fold BN into the preceding conv for inference export.

    w' = w * gamma / sqrt(var + eps)  (per output channel)
    b' = beta - gamma * mean / sqrt(var + eps)
    """
    g = bn_params["gamma"] / jnp.sqrt(bn_params["var"] + eps)
    w = conv_params["w"] * g[:, None, None, None]
    b = bn_params["beta"] - bn_params["mean"] * g
    return {"w": w, "b": b}


# ---------------------------------------------------------------------------
# LayerNorm (BERT path).
# ---------------------------------------------------------------------------
def ln_init(dim: int) -> dict:
    return {"gamma": jnp.ones((dim,), jnp.float32),
            "beta": jnp.zeros((dim,), jnp.float32)}


def ln_apply(params, x, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * params["gamma"] + params["beta"]
