"""Cross-language test vectors: pin Rust quantizers == JAX oracles bit-exactly.

Emits JSON files consumed by ``rust/tests/test_testvec.rs``:

    fixed.json    {m, alpha, w[], q[], code[]} per case
    pot.json      {m, alpha, w[], q[], sign[], exp[]} per case
    apot.json     {m, alpha, w[], q[]} per case
    act.json      {m, alpha, x[], q[], code[]} per case
    rowwise.json  one mixed matrix: w, alpha[], scheme[], q (flattened)
    gemm.json     x, w, alpha[], scheme[], act_alpha, y (flattened)

Values cover grid points, decision boundaries (half-steps, log2 midpoints),
clip edges, and random draws.
"""

from __future__ import annotations

import json
import os

import numpy as np
import jax.numpy as jnp

from .kernels import ref


def _interesting(m, rng, n=64):
    """Boundary-heavy sample of weights in [-1.5, 1.5]."""
    pts = [0.0, 1.0, -1.0, 1.5, -1.5, 0.5, -0.5]
    # fixed grid midpoints
    k = 2 ** (m - 1) - 1
    pts += [(i + 0.5) / k for i in range(k)]
    # pot log-midpoints, nudged off the exact tie: log2 of the true
    # geometric midpoint is exactly -(2i+1)/2, whose rounding depends on
    # the last ulp of the platform's log2 — not a contract we can pin
    # across XLA and Rust libm. +/-1e-3 probes both sides instead.
    kk = 2 ** (m - 1) - 2
    for i in range(kk):
        mid = float(2.0 ** ((-(i) - (i + 1)) / 2.0))
        pts += [mid * (1 + 1e-3), mid * (1 - 1e-3)]
    pts += list(rng.uniform(-1.4, 1.4, size=n))
    return np.asarray(pts, np.float32)


def write_all(out_dir: str, seed: int = 0):
    os.makedirs(out_dir, exist_ok=True)
    rng = np.random.default_rng(seed)

    fixed_cases, pot_cases, apot_cases, act_cases = [], [], [], []
    for m in (2, 3, 4, 8):
        for alpha in (1.0, 0.7, 2.3):
            w = _interesting(m, rng)
            q = np.asarray(ref.fixed_quant(jnp.asarray(w), alpha, m))
            code = np.asarray(ref.fixed_quant_code(jnp.asarray(w), alpha, m))
            fixed_cases.append({"m": m, "alpha": alpha, "w": w.tolist(),
                                "q": q.tolist(), "code": code.tolist()})
    for m in (3, 4, 5):
        for alpha in (1.0, 0.8):
            w = _interesting(m, rng)
            q = np.asarray(ref.pot_quant(jnp.asarray(w), alpha, m))
            s, e = ref.pot_quant_code(jnp.asarray(w), alpha, m)
            pot_cases.append({"m": m, "alpha": alpha, "w": w.tolist(),
                              "q": q.tolist(), "sign": np.asarray(s).tolist(),
                              "exp": np.asarray(e).tolist()})
    for alpha in (1.0, 1.3):
        w = _interesting(4, rng)
        q = np.asarray(ref.apot_quant(jnp.asarray(w), alpha, 4))
        apot_cases.append({"m": 4, "alpha": alpha, "w": w.tolist(), "q": q.tolist()})
    for m in (4, 8):
        for alpha in (1.0, 2.0):
            x = np.concatenate([
                np.asarray([-0.5, 0.0, alpha, 2 * alpha], np.float32),
                rng.uniform(0, 1.5 * alpha, size=32).astype(np.float32)])
            q = np.asarray(ref.act_quant(jnp.asarray(x), alpha, m))
            code = np.asarray(ref.act_quant_code(jnp.asarray(x), alpha, m))
            act_cases.append({"m": m, "alpha": alpha, "x": x.tolist(),
                              "q": q.tolist(), "code": code.tolist()})

    rows, cols = 12, 17
    w = rng.normal(size=(rows, cols)).astype(np.float32) * 0.6
    alpha = np.maximum(np.abs(w).max(axis=1), 1e-8)
    scheme = rng.integers(0, 4, size=rows).astype(np.int32)
    q = np.asarray(ref.rowwise_quant(jnp.asarray(w), jnp.asarray(alpha),
                                     jnp.asarray(scheme)))
    rowwise = {"rows": rows, "cols": cols, "w": w.reshape(-1).tolist(),
               "alpha": alpha.tolist(), "scheme": scheme.tolist(),
               "q": q.reshape(-1).tolist()}

    batch = 5
    x = rng.uniform(0, 1.2, size=(batch, cols)).astype(np.float32)
    y = np.asarray(ref.rowwise_mixed_gemm(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(alpha),
        jnp.asarray(scheme), act_alpha=1.0))
    gemm = {"batch": batch, "rows": rows, "cols": cols,
            "x": x.reshape(-1).tolist(), "w": w.reshape(-1).tolist(),
            "alpha": alpha.tolist(), "scheme": scheme.tolist(),
            "act_alpha": 1.0, "y": y.reshape(-1).tolist()}

    for name, obj in [("fixed", fixed_cases), ("pot", pot_cases),
                      ("apot", apot_cases), ("act", act_cases),
                      ("rowwise", rowwise), ("gemm", gemm)]:
        with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
            json.dump(obj, f)


if __name__ == "__main__":
    import sys

    write_all(sys.argv[1] if len(sys.argv) > 1 else "../artifacts/testvec")
