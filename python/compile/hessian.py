"""Per-filter Hessian max-eigenvalue estimation (paper Eq. 7-8, Alg. 1 l.3-10).

The paper power-iterates the Hessian of each filter W_ij: v_{k+1} = H v_k,
computed as the gradient of (g^T v) (HAWQ's identity, Eq. 8). We batch the
per-filter loops with ``jax.vmap`` over filter-masked probe vectors: for a
layer with F filters, the probe tensor has shape (F, *W.shape) with probe[f]
supported only on filter f's slice, so the restriction of H @ probe[f] to
filter f is exactly the *block* Hessian H_ff @ v_f (cross-filter terms live
outside the restriction). This computes all F power iterations in one
vmapped HVP per step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _layer_hvp(loss_fn, params, layer_path, batch):
    """Build an HVP function over ONE layer's weight tensor.

    layer_path: tuple of keys into params, e.g. ("s0b0", "conv1", "w").
    Returns hvp(v) with v shaped like the layer weights.
    """

    def get(p):
        for k in layer_path:
            p = p[k]
        return p

    def set_(p, w):
        # shallow-copy the path, replace the leaf
        if len(layer_path) == 1:
            return dict(p, **{layer_path[0]: w})
        head = layer_path[0]
        return dict(p, **{head: set_path(p[head], layer_path[1:], w)})

    def set_path(p, path, w):
        if len(path) == 1:
            return dict(p, **{path[0]: w})
        return dict(p, **{path[0]: set_path(p[path[0]], path[1:], w)})

    w0 = get(params)

    def loss_of_w(w):
        return loss_fn(set_(params, w), batch)

    def hvp(v):
        return jax.jvp(jax.grad(loss_of_w), (w0,), (v,))[1]

    return hvp, w0


def filter_max_eigenvalues(loss_fn, params, layer_path, batch,
                           iters: int = 10, seed: int = 0):
    """Max eigenvalue of each filter's block Hessian for one layer.

    Args:
      loss_fn: (params, batch) -> scalar loss (the QAT training loss).
      params: model params pytree.
      layer_path: keys to the layer weight tensor; first axis = filters.
      batch: probe minibatch.
      iters: power-iteration steps (paper caps at 20; 10 converges here).

    Returns: (F,) ndarray of eigenvalue estimates (Rayleigh quotients).
    """
    hvp, w0 = _layer_hvp(loss_fn, params, layer_path, batch)
    F = w0.shape[0]
    flat = w0.reshape(F, -1)
    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, flat.shape, jnp.float32)
    v = v / (jnp.linalg.norm(v, axis=1, keepdims=True) + 1e-12)

    def embed(vf, f):
        """(F, D) row vf -> full weight tensor supported on filter f."""
        z = jnp.zeros_like(flat)
        z = z.at[f].set(vf)
        return z.reshape(w0.shape)

    def one_filter_hvp(vf, f):
        hv = hvp(embed(vf, f))
        return hv.reshape(F, -1)[f]

    batched_hvp = jax.vmap(one_filter_hvp, in_axes=(0, 0))
    idx = jnp.arange(F)

    lam = jnp.zeros((F,), jnp.float32)
    for _ in range(iters):
        hv = batched_hvp(v, idx)  # (F, D)
        lam = jnp.sum(v * hv, axis=1)  # Rayleigh quotient per filter
        nrm = jnp.linalg.norm(hv, axis=1, keepdims=True)
        v = hv / (nrm + 1e-12)
    return jnp.abs(lam)


def all_layer_eigenvalues(loss_fn, params, layer_paths: dict, batch,
                          iters: int = 10, seed: int = 0) -> dict:
    """Run filter_max_eigenvalues for every quantized layer.

    layer_paths: {layer_name: path tuple}; returns {layer_name: (F,) array}.

    Exact per-filter power iteration (Alg. 1 lines 3-7) — O(total_filters)
    HVPs per step. Used by unit tests and small models; the training loop
    defaults to :func:`block_trace_estimates`, which matches the ranking at
    a fraction of the cost.
    """
    return {
        name: filter_max_eigenvalues(loss_fn, params, path, batch, iters, seed)
        for name, path in layer_paths.items()
    }


def block_trace_estimates(loss_fn, params, layer_paths: dict, batch,
                          samples: int = 8, seed: int = 0) -> dict:
    """Per-filter Hessian *block trace* via Hutchinson probing — the fast
    sensitivity scorer (HAWQ-V2's trace metric, filter-granular).

    One full-model HVP per probe: with Rademacher v (entries ±1, independent
    across parameters), E[v_f · (Hv)_f] = tr(H_ff) for every filter f
    simultaneously — cross-block terms vanish in expectation. ``samples``
    HVPs total, vs one HVP *per filter per iteration* for the exact power
    method. Ranking agreement with the exact method is pinned by
    tests/test_hessian.py.

    Returns {layer_name: (F,) trace estimates}.
    """
    leaves, treedef = jax.tree_util.tree_flatten(params)
    sizes = [l.size for l in leaves]

    grad_fn = jax.grad(lambda p: loss_fn(p, batch))

    @jax.jit
    def hvp_full(v_pytree):
        return jax.jvp(grad_fn, (params,), (v_pytree,))[1]

    key = jax.random.PRNGKey(seed)
    acc = {name: jnp.zeros((_rows_of(params, path),), jnp.float32)
           for name, path in layer_paths.items()}
    for s in range(samples):
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, len(leaves))
        v_leaves = [
            jax.random.rademacher(k, (sz,), jnp.float32).reshape(l.shape)
            for k, sz, l in zip(keys, sizes, leaves)
        ]
        v = jax.tree_util.tree_unflatten(treedef, v_leaves)
        hv = hvp_full(v)
        for name, path in layer_paths.items():
            vf = _leaf(v, path)
            hf = _leaf(hv, path)
            F = vf.shape[0]
            acc[name] = acc[name] + jnp.sum(
                (vf * hf).reshape(F, -1), axis=1)
    return {k: jnp.abs(a) / samples for k, a in acc.items()}


def _leaf(p, path):
    for k in path:
        p = p[k]
    return p


def _rows_of(params, path) -> int:
    return _leaf(params, path).shape[0]
