"""Accuracy experiment harness: regenerates Fig. 3, Table 1, and Table 5.

Substituted workloads (DESIGN.md §3): synthetic class-conditional image sets
(`synth10`/`synth100` for CIFAR-10/100, `synth10-64` for ImageNet's role as
the "bigger input" dataset) and synthetic GLUE-like text tasks. We reproduce
the *shape* of the paper's numbers: per-method ordering, the PoT-ratio
degradation without the 5% Fixed-W8A4 class, and its recovery with it.

Usage (from python/):

    python -m compile.experiments fig3   [--quick] [--out ../results]
    python -m compile.experiments table1 [--quick] [--models resnet18]
    python -m compile.experiments table5 [--quick]
    python -m compile.experiments e2e    [--steps 300]

Every command writes `<name>.json` (raw numbers) and `<name>.md` (the
paper-style table) into the output directory.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from . import data, train
from .kernels import ref
from .models import make

# Table 1 method registry: name -> (ratio, nonlinear scheme, use_hessian)
METHODS = {
    "Fixed-W4A4": ((0, 100, 0), ref.POT_W4A4, False),
    "PoT-W4A4": ((100, 0, 0), ref.POT_W4A4, False),
    "APoT-W4A4": ((100, 0, 0), ref.APOT_W4A4, False),
    "PoT+Fixed (50:50)": ((50, 50, 0), ref.POT_W4A4, False),
    "APoT+Fixed (60:40)": ((60, 40, 0), ref.APOT_W4A4, False),
    "Fixed4+Fixed8 (95:5)": ((0, 95, 5), ref.POT_W4A4, True),
    "RMSMP (65:30:5)": ((65, 30, 5), ref.POT_W4A4, True),
}


def _dataset(name: str, n_train: int, n_test: int, seed: int = 0,
             noise: float = 1.4):
    """Noise 1.4 puts the fp32 model around 92-94% (8+ epochs) — high enough to be a
    real task, low enough that 4-bit quantization deltas are visible (the
    regime the paper's CIFAR numbers live in)."""
    if name == "synth10":
        classes, size = 10, 32
    elif name == "synth100":
        classes, size = 100, 32
    elif name == "synth10-64":
        classes, size = 10, 64
    else:
        raise ValueError(name)
    tr = data.image_dataset(classes, n=n_train, size=size, seed=seed, noise=noise)
    te = data.image_dataset(classes, n=n_test, size=size, seed=seed,
                            split="test", noise=noise)
    return classes, size, tr, te


def _train_baseline(model_name, classes, tr, te, epochs, seed=0):
    cfg = make(model_name, num_classes=classes)
    tcfg = train.TrainConfig(epochs=epochs, batch_size=32, seed=seed,
                             lr=8e-3, use_hessian=False)
    res = train.train(cfg, tr, te, tcfg, quant=False)
    return cfg, res


def _finetune(cfg, tr, te, base_params, ratio, nonlinear, use_hessian,
              epochs, seed=0):
    tcfg = train.TrainConfig(epochs=epochs, batch_size=32, seed=seed,
                             lr=2e-3, ratio=ratio, nonlinear=nonlinear,
                             use_hessian=use_hessian,
                             refresh_every=max(epochs, 1))
    return train.train(cfg, tr, te, tcfg, quant=True, init_params=base_params)


# ---------------------------------------------------------------------------
# Fig. 3: accuracy vs PoT ratio, with and without the 5% Fixed-W8A4 class.
# ---------------------------------------------------------------------------
def run_fig3(args):
    ratios = [0, 25, 50, 65, 75, 90, 100]
    models = args.models.split(",")
    datasets = args.datasets.split(",")
    out = {"ratios": ratios, "series": {}}
    for model_name in models:
        for ds in datasets:
            classes, size, tr, te = _dataset(ds, args.n_train, args.n_test, noise=args.noise)
            cfg, base = _train_baseline(model_name, classes, tr, te, args.base_epochs)
            key = f"{model_name}/{ds}"
            print(f"[fig3] {key}: baseline acc {base.eval_acc:.3f}")
            for c_pct, label in ((0, "no-W8A4"), (5, "5%-W8A4")):
                accs = []
                for a in ratios:
                    a_eff = min(a, 100 - c_pct)
                    b = 100 - a_eff - c_pct
                    res = _finetune(cfg, tr, te, base.params, (a_eff, b, c_pct),
                                    ref.POT_W4A4, c_pct > 0, args.ft_epochs)
                    accs.append(res.eval_acc)
                    print(f"  PoT={a}% {label}: acc {res.eval_acc:.3f}", flush=True)
                out["series"][f"{key}/{label}"] = accs
            out["series"][f"{key}/baseline"] = [base.eval_acc] * len(ratios)
    _write(args.out, "fig3", out, _fig3_md(out))


def _fig3_md(out):
    lines = ["# Figure 3 — accuracy vs PoT-W4A4 ratio", "",
             "| series | " + " | ".join(f"{r}%" for r in out["ratios"]) + " |",
             "|" + "---|" * (len(out["ratios"]) + 1)]
    for k, v in sorted(out["series"].items()):
        lines.append(f"| {k} | " + " | ".join(f"{a:.3f}" for a in v) + " |")
    lines += ["", "Shape check: the no-W8A4 series should degrade as the PoT "
              "ratio grows; the 5%-W8A4 series should stay near the baseline "
              "until high ratios (paper Fig. 3)."]
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Table 1: per-scheme accuracy for each model/dataset.
# ---------------------------------------------------------------------------
def run_table1(args):
    models = args.models.split(",")
    datasets = args.datasets.split(",")
    rows = {}
    for model_name in models:
        for ds in datasets:
            classes, size, tr, te = _dataset(ds, args.n_train, args.n_test, noise=args.noise)
            cfg, base = _train_baseline(model_name, classes, tr, te, args.base_epochs)
            key = f"{model_name}/{ds}"
            rows[key] = {"Baseline (W32A32)": base.eval_acc}
            print(f"[table1] {key}: baseline {base.eval_acc:.3f}", flush=True)
            for mname, (ratio, nl, hess) in METHODS.items():
                t0 = time.time()
                # PTQ column: assignment + calibration only (epochs=0) —
                # exposes the raw per-scheme error before QAT recovers it.
                ptq = _finetune(cfg, tr, te, base.params, ratio, nl, hess, 0)
                rows[key][f"{mname} [PTQ]"] = ptq.eval_acc
                res = _finetune(cfg, tr, te, base.params, ratio, nl, hess,
                                args.ft_epochs)
                rows[key][mname] = res.eval_acc
                print(f"  {mname:<22} ptq {ptq.eval_acc:.3f} qat {res.eval_acc:.3f} "
                      f"({time.time()-t0:.0f}s)", flush=True)
    _write(args.out, "table1", rows, _table1_md(rows))


def _table1_md(rows):
    methods = ["Baseline (W32A32)"]
    for m in METHODS:
        methods += [f"{m} [PTQ]", m]
    lines = ["# Table 1 — quantization methods (synthetic substitutes)", "",
             "| method | " + " | ".join(rows) + " |",
             "|" + "---|" * (len(rows) + 1)]
    for m in methods:
        lines.append(f"| {m} | " + " | ".join(
            f"{rows[k].get(m, float('nan')):.3f}" for k in rows) + " |")
    lines += ["", "Shape check (paper Table 1): RMSMP ≈ Fixed4+Fixed8 ≥ "
              "Fixed-W4A4 ≥ APoT ≥ PoT+Fixed ≥ PoT."]
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Table 5: BERT on synthetic SST-2 / MNLI.
# ---------------------------------------------------------------------------
def run_table5(args):
    tasks = ["sst2-syn", "mnli-syn"]
    bert_methods = {
        "Fixed (W4A4)": ((0, 100, 0), ref.POT_W4A4, False),
        "PoT (W4A4)": ((100, 0, 0), ref.POT_W4A4, False),
        "PoT+Fixed": ((50, 50, 0), ref.POT_W4A4, False),
        "RMSMP": ((65, 30, 5), ref.POT_W4A4, True),
    }
    rows = {}
    for task in tasks:
        tok, lab, nc = data.text_dataset(task, n=args.n_train)
        tok_te, lab_te, _ = data.text_dataset(task, n=args.n_test, split="test")
        cfg = make("tinybert", num_classes=nc)
        tcfg = train.TrainConfig(epochs=args.base_epochs, batch_size=32,
                                 lr=3e-3, use_hessian=False)
        base = train.train(cfg, (tok, lab), (tok_te, lab_te), tcfg, quant=False)
        rows[task] = {"Baseline (W32A32)": base.eval_acc}
        print(f"[table5] {task}: baseline {base.eval_acc:.3f}")
        for mname, (ratio, nl, hess) in bert_methods.items():
            res = _finetune(cfg, (tok, lab), (tok_te, lab_te), base.params,
                            ratio, nl, hess, args.ft_epochs)
            rows[task][mname] = res.eval_acc
            print(f"  {mname:<14} acc {res.eval_acc:.3f}")
    _write(args.out, "table5", rows, _table5_md(rows))


def _table5_md(rows):
    methods = ["Baseline (W32A32)", "Fixed (W4A4)", "PoT (W4A4)",
               "PoT+Fixed", "RMSMP"]
    lines = ["# Table 5 — BERT (TinyBERT substitute) on synthetic GLUE", "",
             "| method | " + " | ".join(rows) + " |",
             "|" + "---|" * (len(rows) + 1)]
    for m in methods:
        lines.append(f"| {m} | " + " | ".join(
            f"{rows[k].get(m, float('nan')):.3f}" for k in rows) + " |")
    lines += ["", "Shape check (paper Table 5): all methods within ~0.5% of "
              "baseline (BERT is redundant); RMSMP at or above the mixes."]
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Ablation: the two assignment rules of Alg. 1 (DESIGN.md design choices).
#   A. Fixed-W8A4 selection: Hessian trace vs weight-norm vs random.
#   B. PoT/Fixed split: low-variance->PoT (paper) vs random vs inverted.
# ---------------------------------------------------------------------------
def run_ablation(args):
    from . import assignment as asg

    classes, size, tr, te = _dataset("synth10", args.n_train, args.n_test, noise=args.noise)
    cfg, base = _train_baseline("resnet18", classes, tr, te, args.base_epochs)
    rows = {"baseline": base.eval_acc}
    print(f"[ablation] baseline {base.eval_acc:.3f}")

    def finetune_with(assign_override=None, use_hessian=True, seed=0):
        tcfg = train.TrainConfig(epochs=args.ft_epochs, batch_size=32,
                                 lr=2e-3, ratio=(65, 30, 5), seed=seed,
                                 use_hessian=use_hessian,
                                 refresh_every=max(args.ft_epochs, 1))
        if assign_override is not None:
            orig = asg.assign_layer
            asg.assign_layer = assign_override
            try:
                return train.train(cfg, tr, te, tcfg, quant=True,
                                   init_params=base.params)
            finally:
                asg.assign_layer = orig
        return train.train(cfg, tr, te, tcfg, quant=True,
                           init_params=base.params)

    # A1 paper: hessian + variance
    rows["hessian+variance (paper)"] = finetune_with().eval_acc
    # A2: weight-norm proxy instead of hessian
    rows["norm+variance"] = finetune_with(use_hessian=False).eval_acc

    # B: scheme split rules (capture the unpatched rule first — the
    # overrides below replace asg.assign_layer while they run)
    paper_rule = asg.assign_layer

    def random_split(w, ratio, eigen=None, nonlinear=ref.POT_W4A4):
        rng = np.random.default_rng(0)
        rows_n = np.asarray(w).shape[0]
        na, nb, nc = asg.ratio_counts(rows_n, ratio)
        s = np.array([nonlinear] * na + [ref.FIXED_W4A4] * nb
                     + [ref.FIXED_W8A4] * nc, np.int32)
        rng.shuffle(s)
        return s

    def inverted_variance(w, ratio, eigen=None, nonlinear=ref.POT_W4A4):
        s = paper_rule(w, ratio, eigen, nonlinear)
        # swap the PoT and Fixed4 populations (high-variance rows -> PoT)
        out = s.copy()
        out[s == ref.POT_W4A4] = ref.FIXED_W4A4
        pot_n = int((s == ref.POT_W4A4).sum())
        fixed_idx = np.where(s == ref.FIXED_W4A4)[0]
        var = np.asarray(w).var(axis=1)
        hi = fixed_idx[np.argsort(-var[fixed_idx])][:pot_n]
        out[hi] = ref.POT_W4A4
        return out

    rows["random split"] = finetune_with(random_split, use_hessian=False).eval_acc
    rows["inverted variance"] = finetune_with(inverted_variance, use_hessian=False).eval_acc

    for k, v in rows.items():
        print(f"  {k:<28} {v:.3f}")
    md = ["# Ablation — Alg. 1 assignment rules (resnet18/synth10, 65:30:5)",
          "", "| rule | top-1 |", "|---|---|"]
    md += [f"| {k} | {v:.3f} |" for k, v in rows.items()]
    md += ["", "Expected shape: paper rule ≥ norm proxy ≥ random/inverted."]
    _write(args.out, "ablation", rows, "\n".join(md))


# ---------------------------------------------------------------------------
# E2E driver: QAT from scratch with loss logging (EXPERIMENTS.md §E2E).
# ---------------------------------------------------------------------------
def run_e2e(args):
    classes, size, tr, te = _dataset("synth10", args.n_train, args.n_test, noise=args.noise)
    cfg = make("resnet18", num_classes=classes)
    epochs = max(1, args.steps // max(len(tr[0]) // 32, 1))
    tcfg = train.TrainConfig(epochs=epochs, batch_size=32, lr=8e-3,
                             ratio=(65, 30, 5), use_hessian=True,
                             refresh_every=max(epochs // 2, 1))
    t0 = time.time()
    res = train.train(cfg, tr, te, tcfg, quant=True, verbose=True)
    out = {
        "model": "resnet18", "dataset": "synth10",
        "steps": res.history[-1][0] if res.history else 0,
        "loss_curve": res.history,
        "final_acc": res.eval_acc,
        "train_seconds": time.time() - t0,
    }
    md = ["# E2E QAT driver — resnet18 / synth10 (RMSMP 65:30:5)", "",
          f"final eval acc: **{res.eval_acc:.3f}** after {out['steps']} steps "
          f"({out['train_seconds']:.0f}s)", "", "| step | loss | batch acc |",
          "|---|---|---|"]
    md += [f"| {s} | {l:.4f} | {a:.3f} |" for (s, l, a) in res.history]
    _write(args.out, "e2e", out, "\n".join(md))


def _write(out_dir, name, obj, md):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=1)
    with open(os.path.join(out_dir, f"{name}.md"), "w") as f:
        f.write(md + "\n")
    print(f"[{name}] wrote {out_dir}/{name}.json and .md")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("cmd", choices=["fig3", "table1", "table5", "e2e", "ablation"])
    ap.add_argument("--out", default="../results")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--models", default="resnet18")
    ap.add_argument("--datasets", default="synth10")
    ap.add_argument("--n-train", type=int, default=2048)
    ap.add_argument("--n-test", type=int, default=512)
    ap.add_argument("--base-epochs", type=int, default=6)
    ap.add_argument("--ft-epochs", type=int, default=3)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--noise", type=float, default=1.4)
    args = ap.parse_args()
    if args.quick:
        args.n_train, args.n_test = 512, 256
        args.base_epochs, args.ft_epochs = 2, 1
    {"fig3": run_fig3, "table1": run_table1, "table5": run_table5,
     "e2e": run_e2e, "ablation": run_ablation}[args.cmd](args)


if __name__ == "__main__":
    main()
