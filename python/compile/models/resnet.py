"""ResNet-CIFAR family (the paper's ResNet-18 / ResNet-50 stand-ins).

Substitution (DESIGN.md §3): the paper trains torchvision ResNet-18/50 on
CIFAR/ImageNet GPUs; we build the same topologies (basic blocks for -18,
bottleneck blocks for -50) at CIFAR scale and reduced width so the full QAT
sweeps of Table 1 / Fig. 3 run on one CPU. Filter counts per layer stay
>= 16 so the row-wise 65:30:5 split and the top-5% Hessian rule remain
meaningful.

Every conv and the final FC are quantized (RMSMP quantizes first/last layers
like any other layer — the ✓ column in Tables 2-4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L


def config(name: str = "resnet18", num_classes: int = 10, width: int = 16,
           in_ch: int = 3) -> dict:
    """Model config. name in {resnet18, resnet50}."""
    if name == "resnet18":
        blocks, bottleneck = (2, 2, 2), False
    elif name == "resnet50":
        blocks, bottleneck = (3, 4, 3), True
    else:
        raise ValueError(f"unknown resnet {name!r}")
    return {
        "arch": "resnet",
        "name": name,
        "blocks": blocks,
        "bottleneck": bottleneck,
        "widths": (width, 2 * width, 4 * width),
        "num_classes": num_classes,
        "in_ch": in_ch,
        "expansion": 2 if bottleneck else 1,
    }


def _block_convs(cfg, in_ch, out_ch, stride, rng):
    """Params for one residual block; returns (params, conv_specs).

    conv_specs: list of (key, rows, stride, k) for qstate construction.
    """
    e = cfg["expansion"]
    p, spec = {}, []
    rngs = jax.random.split(rng, 4)
    if cfg["bottleneck"]:
        mid = out_ch
        p["conv1"] = L.conv_init(rngs[0], in_ch, mid, 1)
        p["conv2"] = L.conv_init(rngs[1], mid, mid, 3)
        p["conv3"] = L.conv_init(rngs[2], mid, out_ch * e, 1)
        p["bn1"], p["bn2"], p["bn3"] = L.bn_init(mid), L.bn_init(mid), L.bn_init(out_ch * e)
        spec = [("conv1", mid, 1, 1), ("conv2", mid, stride, 3),
                ("conv3", out_ch * e, 1, 1)]
    else:
        p["conv1"] = L.conv_init(rngs[0], in_ch, out_ch, 3)
        p["conv2"] = L.conv_init(rngs[1], out_ch, out_ch, 3)
        p["bn1"], p["bn2"] = L.bn_init(out_ch), L.bn_init(out_ch)
        spec = [("conv1", out_ch, stride, 3), ("conv2", out_ch, 1, 3)]
    if stride != 1 or in_ch != out_ch * e:
        p["down"] = L.conv_init(rngs[3], in_ch, out_ch * e, 1)
        p["bn_down"] = L.bn_init(out_ch * e)
        spec.append(("down", out_ch * e, stride, 1))
    return p, spec


def init(rng, cfg) -> tuple[dict, dict]:
    """Returns (params, qstates). qstates keys are the quantized layer names."""
    rngs = jax.random.split(rng, 2 + sum(cfg["blocks"]))
    params = {"stem": L.conv_init(rngs[0], cfg["in_ch"], cfg["widths"][0], 3),
              "bn_stem": L.bn_init(cfg["widths"][0])}
    qstates = {"stem": L.default_qstate(cfg["widths"][0])}
    in_ch = cfg["widths"][0]
    ri = 1
    e = cfg["expansion"]
    for s, (n, w) in enumerate(zip(cfg["blocks"], cfg["widths"])):
        for b in range(n):
            stride = 2 if (b == 0 and s > 0) else 1
            name = f"s{s}b{b}"
            bp, spec = _block_convs(cfg, in_ch, w, stride, rngs[ri])
            ri += 1
            params[name] = bp
            for key, rows, _, _ in spec:
                qstates[f"{name}.{key}"] = L.default_qstate(rows)
            in_ch = w * e
    params["fc"] = L.linear_init(rngs[-1], in_ch, cfg["num_classes"])
    qstates["fc"] = L.default_qstate(cfg["num_classes"])
    return params, qstates


def _apply_block(cfg, name, p, qstates, x, stride, train, quant, new_params):
    """One residual block. ``stride`` is static (2 for the first block of
    stages > 0, else 1) — the same rule used at init time."""
    qs = (lambda k: qstates[f"{name}.{k}"]) if quant else (lambda k: None)
    np_ = {}
    if cfg["bottleneck"]:
        h, np_["bn1"] = L.bn_apply(p["bn1"], L.conv_apply(p["conv1"], x, qs("conv1")), train)
        h = jax.nn.relu(h)
        h, np_["bn2"] = L.bn_apply(p["bn2"], L.conv_apply(p["conv2"], h, qs("conv2"), stride=stride), train)
        h = jax.nn.relu(h)
        h, np_["bn3"] = L.bn_apply(p["bn3"], L.conv_apply(p["conv3"], h, qs("conv3")), train)
    else:
        h, np_["bn1"] = L.bn_apply(p["bn1"], L.conv_apply(p["conv1"], x, qs("conv1"), stride=stride), train)
        h = jax.nn.relu(h)
        h, np_["bn2"] = L.bn_apply(p["bn2"], L.conv_apply(p["conv2"], h, qs("conv2")), train)
    if "down" in p:
        sc, np_["bn_down"] = L.bn_apply(
            p["bn_down"], L.conv_apply(p["down"], x, qs("down"), stride=stride), train)
    else:
        sc = x
    for k in ("conv1", "conv2", "conv3", "down"):
        if k in p:
            np_[k] = p[k]
    new_params[name] = np_
    return jax.nn.relu(h + sc)


def apply(params, qstates, x, cfg, train: bool = False, quant: bool = True):
    """Forward pass. Returns (logits, new_params) — new_params carries BN
    running-stat updates when train=True."""
    new_params = {}
    qs = qstates["stem"] if quant else None
    # The stem input is the image itself (not post-ReLU); quantizing raw
    # pixels with an unsigned quantizer is fine because data.py normalizes
    # images into [0, 1).
    h, new_params["bn_stem"] = L.bn_apply(
        params["bn_stem"], L.conv_apply(params["stem"], x, qs), train)
    h = jax.nn.relu(h)
    new_params["stem"] = params["stem"]
    for s, n in enumerate(cfg["blocks"]):
        for b in range(n):
            name = f"s{s}b{b}"
            stride = 2 if (b == 0 and s > 0) else 1
            h = _apply_block(cfg, name, params[name], qstates, h, stride,
                             train, quant, new_params)
    h = jnp.mean(h, axis=(2, 3))
    logits = L.linear_apply(params["fc"], h, qstates["fc"] if quant else None)
    new_params["fc"] = params["fc"]
    return logits, new_params


def quantized_weight_views(params, cfg) -> dict:
    """name -> (rows, cols) 2-D weight views for assignment/hessian/export."""
    out = {"stem": params["stem"]["w"].reshape(params["stem"]["w"].shape[0], -1)}
    for s, n in enumerate(cfg["blocks"]):
        for b in range(n):
            name = f"s{s}b{b}"
            for k in ("conv1", "conv2", "conv3", "down"):
                if k in params[name]:
                    w = params[name][k]["w"]
                    out[f"{name}.{k}"] = w.reshape(w.shape[0], -1)
    out["fc"] = params["fc"]["w"]
    return out
