"""Model zoo registry: resnet18/resnet50/mobilenetv2/tinybert.

Each model module exposes::

    config(**kw) -> cfg          # static config dict (cfg["arch"] selects)
    init(rng, cfg) -> (params, qstates)
    apply(params, qstates, x, cfg, train, quant) -> (logits, new_params)
    quantized_weight_views(params, cfg) -> {layer_name: (rows, cols) view}
"""

from . import bert, mobilenet, resnet

_ARCH = {"resnet": resnet, "mobilenet": mobilenet, "bert": bert}


def module_for(cfg):
    """Dispatch on cfg['arch']."""
    return _ARCH[cfg["arch"]]


def make(name: str, num_classes: int = 10, **kw):
    """Build a model cfg by short name."""
    if name in ("resnet18", "resnet50"):
        cfg = resnet.config(name, num_classes=num_classes, **kw)
    elif name == "mobilenetv2":
        cfg = mobilenet.config(num_classes=num_classes, **kw)
    elif name == "tinybert":
        cfg = bert.config(num_classes=num_classes, **kw)
    else:
        raise ValueError(f"unknown model {name!r}")
    return cfg
