"""TinyBERT-style transformer encoder (the paper's BERT stand-in, Table 5).

Substitution (DESIGN.md §3): BERT-base on SST-2/MNLI becomes a 2-layer
encoder (d_model 64, 2 heads, d_ff 128) on synthetic sequence-classification
corpora from data.py. The quantized matrices — Wq/Wk/Wv/Wo and the two FFN
matrices per layer, plus the classifier head — have exactly the row/column
structure the row-wise assignment operates on in Q-BERT-style quantization.

Activation quantization uses the *signed* Fixed quantizer (transformer
activations are not post-ReLU), matching how Q-BERT treats GELU inputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L
from ..kernels import ref


def config(num_classes: int = 2, vocab: int = 256, d_model: int = 64,
           n_heads: int = 2, d_ff: int = 128, n_layers: int = 2,
           max_len: int = 32) -> dict:
    return {
        "arch": "bert",
        "name": f"tinybert{n_layers}",
        "vocab": vocab,
        "d_model": d_model,
        "n_heads": n_heads,
        "d_ff": d_ff,
        "n_layers": n_layers,
        "max_len": max_len,
        "num_classes": num_classes,
    }


_QLAYERS = ("wq", "wk", "wv", "wo", "ff1", "ff2")


def init(rng, cfg) -> tuple[dict, dict]:
    d, f = cfg["d_model"], cfg["d_ff"]
    rngs = jax.random.split(rng, 3 + 6 * cfg["n_layers"])
    params = {
        "embed": jax.random.normal(rngs[0], (cfg["vocab"], d), jnp.float32) * 0.02,
        "pos": jax.random.normal(rngs[1], (cfg["max_len"], d), jnp.float32) * 0.02,
    }
    qstates = {}
    ri = 2
    for i in range(cfg["n_layers"]):
        blk = {}
        dims = {"wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
                "ff1": (d, f), "ff2": (f, d)}
        for k in _QLAYERS:
            i_d, o_d = dims[k]
            blk[k] = L.linear_init(rngs[ri], i_d, o_d); ri += 1
            qstates[f"l{i}.{k}"] = L.default_qstate(o_d)
        blk["ln1"] = L.ln_init(d)
        blk["ln2"] = L.ln_init(d)
        params[f"l{i}"] = blk
    params["cls"] = L.linear_init(rngs[-1], d, cfg["num_classes"])
    qstates["cls"] = L.default_qstate(cfg["num_classes"])
    return params, qstates


def _qlinear_signed(p, x, qstate):
    """Linear with signed activation quant + row-wise mixed weight quant."""
    if qstate is None:
        return x @ p["w"].T + p["b"]
    xq = L.fake_quant_act(x, qstate, signed=True)
    w = L.fake_quant_weight(p["w"], qstate)
    return xq @ w.T + p["b"]


def apply(params, qstates, tokens, cfg, train: bool = False, quant: bool = True):
    """tokens: (batch, seq) int32. Returns (logits, params) — no BN state."""
    d, nh = cfg["d_model"], cfg["n_heads"]
    hd = d // nh
    seq = tokens.shape[1]
    h = params["embed"][tokens] + params["pos"][:seq]
    for i in range(cfg["n_layers"]):
        blk = params[f"l{i}"]
        qs = (lambda k: qstates[f"l{i}.{k}"]) if quant else (lambda k: None)
        x = L.ln_apply(blk["ln1"], h)
        B = x.shape[0]

        def heads(t):
            return t.reshape(B, seq, nh, hd).transpose(0, 2, 1, 3)

        q = heads(_qlinear_signed(blk["wq"], x, qs("wq")))
        k = heads(_qlinear_signed(blk["wk"], x, qs("wk")))
        v = heads(_qlinear_signed(blk["wv"], x, qs("wv")))
        att = jax.nn.softmax(q @ k.transpose(0, 1, 3, 2) / jnp.sqrt(hd), axis=-1)
        ctx = (att @ v).transpose(0, 2, 1, 3).reshape(B, seq, d)
        h = h + _qlinear_signed(blk["wo"], ctx, qs("wo"))

        x = L.ln_apply(blk["ln2"], h)
        f = jax.nn.gelu(_qlinear_signed(blk["ff1"], x, qs("ff1")))
        h = h + _qlinear_signed(blk["ff2"], f, qs("ff2"))
    pooled = jnp.mean(h, axis=1)
    logits = _qlinear_signed(params["cls"], pooled, qstates["cls"] if quant else None)
    return logits, params


def quantized_weight_views(params, cfg) -> dict:
    out = {}
    for i in range(cfg["n_layers"]):
        for k in _QLAYERS:
            out[f"l{i}.{k}"] = params[f"l{i}"][k]["w"]
    out["cls"] = params["cls"]["w"]
    return out
