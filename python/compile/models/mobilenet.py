"""MobileNetV2-style model (the paper's MobileNet-v2 stand-in).

Inverted-residual blocks with expansion, depthwise 3x3, and linear
bottleneck, scaled to CIFAR resolution and reduced width (DESIGN.md §3).
Depthwise convs have one input channel per filter, so each depthwise filter
is a 9-element row — the hardest case for row-wise assignment (tiny rows,
many filters), which is why the paper's MobileNet numbers drop the most
under PoT.

Note: depthwise + pointwise convs are quantized per filter like any other
layer; the fc head too.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L

# (expansion t, out_ch c, repeats n, stride s) — MobileNetV2 table 2, scaled.
_BLOCKS = (
    (1, 8, 1, 1),
    (4, 12, 2, 1),
    (4, 16, 2, 2),
    (4, 24, 2, 2),
    (4, 32, 1, 1),
)


def config(num_classes: int = 10, width_mult: float = 1.0, in_ch: int = 3) -> dict:
    def c(ch):
        return max(8, int(ch * width_mult))

    return {
        "arch": "mobilenet",
        "name": "mobilenetv2",
        "blocks": tuple((t, c(ch), n, s) for (t, ch, n, s) in _BLOCKS),
        "stem_ch": c(8),
        "head_ch": c(64),
        "num_classes": num_classes,
        "in_ch": in_ch,
    }


def init(rng, cfg) -> tuple[dict, dict]:
    params, qstates = {}, {}
    n_blocks = sum(n for (_, _, n, _) in cfg["blocks"])
    rngs = jax.random.split(rng, 3 + 3 * n_blocks)
    ri = 0

    params["stem"] = L.conv_init(rngs[ri], cfg["in_ch"], cfg["stem_ch"], 3); ri += 1
    params["bn_stem"] = L.bn_init(cfg["stem_ch"])
    qstates["stem"] = L.default_qstate(cfg["stem_ch"])

    in_ch = cfg["stem_ch"]
    bi = 0
    for (t, c, n, s) in cfg["blocks"]:
        for j in range(n):
            name = f"ir{bi}"
            stride = s if j == 0 else 1
            mid = in_ch * t
            p = {}
            if t != 1:
                p["expand"] = L.conv_init(rngs[ri], in_ch, mid, 1); ri += 1
                p["bn_e"] = L.bn_init(mid)
                qstates[f"{name}.expand"] = L.default_qstate(mid)
            # depthwise: OIHW with I=1, groups=mid
            p["dw"] = {"w": jax.random.normal(rngs[ri], (mid, 1, 3, 3), jnp.float32)
                       * jnp.sqrt(2.0 / 9.0)}; ri += 1
            p["bn_d"] = L.bn_init(mid)
            qstates[f"{name}.dw"] = L.default_qstate(mid)
            p["project"] = L.conv_init(rngs[ri], mid, c, 1); ri += 1
            p["bn_p"] = L.bn_init(c)
            qstates[f"{name}.project"] = L.default_qstate(c)
            params[name] = p
            in_ch = c
            bi += 1

    params["head"] = L.conv_init(rngs[ri], in_ch, cfg["head_ch"], 1); ri += 1
    params["bn_head"] = L.bn_init(cfg["head_ch"])
    qstates["head"] = L.default_qstate(cfg["head_ch"])
    params["fc"] = L.linear_init(rngs[-1], cfg["head_ch"], cfg["num_classes"])
    qstates["fc"] = L.default_qstate(cfg["num_classes"])
    cfg["n_ir"] = bi
    return params, qstates


def _block_strides(cfg):
    out = []
    for (t, c, n, s) in cfg["blocks"]:
        out.extend([s if j == 0 else 1 for j in range(n)])
    return out


def apply(params, qstates, x, cfg, train: bool = False, quant: bool = True):
    new_params = {}
    qs = (lambda k: qstates[k]) if quant else (lambda k: None)
    h, new_params["bn_stem"] = L.bn_apply(
        params["bn_stem"], L.conv_apply(params["stem"], x, qs("stem")), train)
    h = jax.nn.relu(h)
    new_params["stem"] = params["stem"]

    strides = _block_strides(cfg)
    for bi, stride in enumerate(strides):
        name = f"ir{bi}"
        p = params[name]
        np_ = {}
        inp = h
        if "expand" in p:
            h, np_["bn_e"] = L.bn_apply(p["bn_e"], L.conv_apply(p["expand"], h, qs(f"{name}.expand")), train)
            h = jax.nn.relu(h)
        mid = p["dw"]["w"].shape[0]
        h, np_["bn_d"] = L.bn_apply(
            p["bn_d"],
            L.conv_apply(p["dw"], h, qs(f"{name}.dw"), stride=stride, groups=mid),
            train)
        h = jax.nn.relu(h)
        # linear bottleneck: no ReLU after projection
        h, np_["bn_p"] = L.bn_apply(p["bn_p"], L.conv_apply(p["project"], h, qs(f"{name}.project")), train)
        if stride == 1 and inp.shape == h.shape:
            h = h + inp
        for k in ("expand", "dw", "project"):
            if k in p:
                np_[k] = p[k]
        new_params[name] = np_

    h, new_params["bn_head"] = L.bn_apply(
        params["bn_head"], L.conv_apply(params["head"], h, qs("head")), train)
    h = jax.nn.relu(h)
    new_params["head"] = params["head"]
    h = jnp.mean(h, axis=(2, 3))
    logits = L.linear_apply(params["fc"], h, qstates["fc"] if quant else None)
    new_params["fc"] = params["fc"]
    return logits, new_params


def quantized_weight_views(params, cfg) -> dict:
    out = {"stem": params["stem"]["w"].reshape(params["stem"]["w"].shape[0], -1)}
    bi = 0
    for (t, c, n, s) in cfg["blocks"]:
        for _ in range(n):
            name = f"ir{bi}"
            p = params[name]
            for k in ("expand", "dw", "project"):
                if k in p:
                    w = p[k]["w"]
                    out[f"{name}.{k}"] = w.reshape(w.shape[0], -1)
            bi += 1
    out["head"] = params["head"]["w"].reshape(params["head"]["w"].shape[0], -1)
    out["fc"] = params["fc"]["w"]
    return out
