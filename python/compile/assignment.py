"""Row-wise scheme/precision assignment (paper Alg. 1, lines 2-14).

Given the offline ratio  PoT-4 : Fixed-4 : Fixed-8 = A : B : C  (A+B+C=100),
for each layer:

1. rows with top-C% Hessian max eigenvalue              -> Fixed-W8A4
2. remaining rows sorted by weight variance; the lowest
   A/(A+B) fraction                                      -> PoT-W4A4
3. the rest                                              -> Fixed-W4A4

The ratio is enforced *exactly* per layer (layer-wise uniformality): counts
are rounded with largest-remainder so every layer has the same scheme mix —
the property the heterogeneous GEMM cores rely on (DESIGN.md §1).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .kernels import ref


def ratio_counts(rows: int, ratio: tuple[int, int, int]) -> tuple[int, int, int]:
    """Largest-remainder split of ``rows`` into the A:B:C ratio."""
    a, b, c = ratio
    tot = a + b + c
    exact = np.array([rows * a / tot, rows * b / tot, rows * c / tot])
    base = np.floor(exact).astype(int)
    rem = rows - base.sum()
    order = np.argsort(-(exact - base))
    for i in range(rem):
        base[order[i]] += 1
    return int(base[0]), int(base[1]), int(base[2])


def assign_layer(w2d, ratio: tuple[int, int, int], eigen=None,
                 nonlinear: int = ref.POT_W4A4) -> np.ndarray:
    """Scheme codes for one layer's (rows, cols) weight view.

    eigen: optional (rows,) Hessian max-eigenvalue estimates. When absent
    (e.g. before the first Hessian pass) the C% falls back to weight-norm
    ranking, which HAWQ shows is the zeroth-order proxy.
    nonlinear: scheme code for the non-linear class (PoT for RMSMP; APoT for
    the MSQ-style baseline rows of Tables 1/6).
    """
    w = np.asarray(w2d)
    rows = w.shape[0]
    na, nb, nc = ratio_counts(rows, ratio)

    sens = np.asarray(eigen) if eigen is not None else np.linalg.norm(w, axis=1)
    scheme = np.full((rows,), ref.FIXED_W4A4, np.int32)

    # 1. top-C% most sensitive rows get the higher precision.
    hi = np.argsort(-sens, kind="stable")[:nc]
    scheme[hi] = ref.FIXED_W8A4

    # 2. remaining rows: the na lowest-variance rows -> non-linear scheme
    #    (PoT levels crowd near zero, so it fits low-variance rows, §3.1).
    rest = np.setdiff1d(np.arange(rows), hi, assume_unique=False)
    var = w.var(axis=1)
    rest_sorted = rest[np.argsort(var[rest], kind="stable")]
    scheme[rest_sorted[:na]] = nonlinear
    # rest default to Fixed-W4A4 (nb rows)
    return scheme


def assign_model(weight_views: dict, ratio: tuple[int, int, int],
                 eigens: dict | None = None,
                 nonlinear: int = ref.POT_W4A4) -> dict:
    """Assign schemes for every quantized layer; returns {name: (rows,) i32}."""
    out = {}
    for name, w2d in weight_views.items():
        e = eigens.get(name) if eigens else None
        out[name] = assign_layer(w2d, ratio, e, nonlinear)
    return out


def update_qstates(qstates: dict, weight_views: dict,
                   ratio: tuple[int, int, int], eigens: dict | None = None,
                   nonlinear: int = ref.POT_W4A4) -> dict:
    """New qstates with refreshed schemes and per-row alphas (Alg. 1 l.2-14)."""
    schemes = assign_model(weight_views, ratio, eigens, nonlinear)
    new = {}
    for name, qs in qstates.items():
        w2d = weight_views[name]
        new[name] = dict(qs, scheme=jnp.asarray(schemes[name]),
                         w_alpha=ref.default_alpha(w2d, axis=1))
    return new


def scheme_histogram(qstates: dict) -> dict:
    """Per-layer counts of (PoT4, Fixed4, Fixed8) — used by tests and the
    manifest to verify layer-wise uniformality."""
    out = {}
    for name, qs in qstates.items():
        s = np.asarray(qs["scheme"])
        out[name] = (int((s == ref.POT_W4A4).sum()),
                     int((s == ref.FIXED_W4A4).sum()),
                     int((s == ref.FIXED_W8A4).sum()))
    return out


def equivalent_bits(qstates: dict) -> float:
    """Weighted average weight bit-width (the paper's 'equivalent precision'):
    PoT4 and Fixed4 rows count 4 bits, Fixed8 rows count 8."""
    tot, bits = 0, 0.0
    for qs in qstates.values():
        s = np.asarray(qs["scheme"])
        tot += s.size
        bits += 4.0 * (s != ref.FIXED_W8A4).sum() + 8.0 * (s == ref.FIXED_W8A4).sum()
    return bits / max(tot, 1)
