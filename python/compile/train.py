"""QAT training loop (paper Alg. 1, lines 15-20).

SGD + momentum with cosine decay, STE gradients through the fake quantizers,
and periodic assignment refresh (Hessian + variance, every ``refresh_every``
epochs — the paper uses 10). Works for every model in the zoo; the loss is
softmax cross-entropy throughout (classification in all of the paper's
tasks).

BN running statistics ride along in ``params`` but receive no gradient: the
train step overwrites them from the forward pass's ``new_params`` after the
SGD update.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from . import assignment, data, hessian
from .models import module_for

_BN_KEYS = ("mean", "var")


@dataclass
class TrainConfig:
    lr: float = 8e-3
    momentum: float = 0.9
    weight_decay: float = 1e-4
    epochs: int = 4
    batch_size: int = 32
    refresh_every: int = 2        # epochs between assignment refreshes
    hessian_iters: int = 5        # power-iteration steps (paper caps at 20)
    hessian_batch: int = 32
    use_hessian: bool = True      # False -> weight-norm proxy (ablation)
    ratio: tuple = (65, 30, 5)    # PoT4 : Fixed4 : Fixed8
    nonlinear: int = 0            # scheme code of the non-linear class
    act_alpha_pct: float = 99.5   # activation clip percentile
    seed: int = 0
    log_every: int = 50


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def _is_bn_stat(path) -> bool:
    return any(getattr(k, "key", None) in _BN_KEYS for k in path)


def make_train_step(model, cfg, quant: bool, tcfg: TrainConfig, total_steps: int):
    """Build the jitted SGD/momentum train step (closes over static config)."""

    def loss_fn(params, qstates, batch):
        x, y = batch
        logits, new_params = model.apply(params, qstates, x, cfg,
                                         train=True, quant=quant)
        loss = cross_entropy(logits, y)
        acc = jnp.mean((jnp.argmax(logits, 1) == y).astype(jnp.float32))
        return loss, (new_params, acc)

    @jax.jit
    def step(params, qstates, vel, batch, it):
        (loss, (new_params, acc)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, qstates, batch)
        lr = 0.5 * tcfg.lr * (1 + jnp.cos(jnp.pi * it / total_steps))

        def upd(path, p, g, v):
            if _is_bn_stat(path):
                return p, v
            g = g + tcfg.weight_decay * p
            v = tcfg.momentum * v + g
            return p - lr * v, v

        flat = jax.tree_util.tree_map_with_path(
            lambda path, p, g, v: upd(path, p, g, v), params, grads, vel)
        new_p = jax.tree_util.tree_map(lambda t: t[0], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree_util.tree_map(lambda t: t[1], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        # overwrite BN running stats from the forward pass
        new_p = jax.tree_util.tree_map_with_path(
            lambda path, p, s: s if _is_bn_stat(path) else p, new_p, new_params)
        return new_p, new_v, loss, acc

    return step, loss_fn


def evaluate(model, cfg, params, qstates, x, y, quant: bool,
             batch_size: int = 128) -> float:
    """Top-1 accuracy over (x, y)."""
    correct, n = 0, 0
    apply_fn = jax.jit(lambda p, q, xb: model.apply(p, q, xb, cfg,
                                                    train=False, quant=quant)[0])
    for i in range(0, len(x), batch_size):
        xb = jnp.asarray(x[i:i + batch_size])
        yb = y[i:i + batch_size]
        logits = apply_fn(params, qstates, xb)
        correct += int((np.argmax(np.asarray(logits), 1) == yb).sum())
        n += len(yb)
    return correct / max(n, 1)


def _layer_paths(cfg, qstates) -> dict:
    """Map quantized-layer names to params paths ('a.b' -> ('a','b','w'))."""
    return {name: tuple(name.split(".")) + ("w",) for name in qstates}


def refresh_assignment(model, cfg, params, qstates, tcfg: TrainConfig,
                       batch, loss_fn) -> dict:
    """Alg. 1 lines 2-14: Hessian top-C% + variance split, exact ratio."""
    views = model.quantized_weight_views(params, cfg)
    eigens = None
    if tcfg.use_hessian and tcfg.ratio[2] > 0:
        paths = _layer_paths(cfg, qstates)
        lf = lambda p, b: loss_fn(p, qstates, b)[0]
        eigens = hessian.block_trace_estimates(
            lf, params, paths, batch, samples=tcfg.hessian_iters, seed=tcfg.seed)
    return assignment.update_qstates(
        qstates, views, tcfg.ratio, eigens,
        nonlinear=tcfg.nonlinear)


@dataclass
class TrainResult:
    params: dict = None
    qstates: dict = None
    history: list = field(default_factory=list)  # (step, loss, acc)
    eval_acc: float = 0.0
    train_seconds: float = 0.0


def train(model_cfg, train_set, test_set, tcfg: TrainConfig,
          quant: bool = True, init_params=None, init_qstates=None,
          verbose: bool = False) -> TrainResult:
    """Train (or QAT-finetune, when init_params given) a model.

    train_set/test_set: (inputs, labels) numpy arrays.
    """
    model = module_for(model_cfg)
    rng = jax.random.PRNGKey(tcfg.seed)
    params, qstates = model.init(rng, model_cfg)
    if init_params is not None:
        params = init_params
    if init_qstates is not None:
        qstates = init_qstates

    x_tr, y_tr = train_set
    steps_per_epoch = max(len(x_tr) // tcfg.batch_size, 1)
    total = steps_per_epoch * tcfg.epochs
    step_fn, loss_fn = make_train_step(model, model_cfg, quant, tcfg, total)

    vel = jax.tree_util.tree_map(jnp.zeros_like, params)
    res = TrainResult()
    t0 = time.time()
    it = 0
    probe = (jnp.asarray(x_tr[: tcfg.hessian_batch]),
             jnp.asarray(y_tr[: tcfg.hessian_batch]))

    if quant and tcfg.epochs == 0:
        # post-training quantization: assign + calibrate, no finetuning
        qstates = refresh_assignment(model, model_cfg, params, qstates,
                                     tcfg, probe, loss_fn)
        qstates = _calibrate_act(model, model_cfg, params, qstates,
                                 probe[0], tcfg.act_alpha_pct)

    for epoch in range(tcfg.epochs):
        if quant and epoch % tcfg.refresh_every == 0:
            qstates = refresh_assignment(model, model_cfg, params, qstates,
                                         tcfg, probe, loss_fn)
            # calibrate activation clips from data percentile
            qstates = _calibrate_act(model, model_cfg, params, qstates,
                                     probe[0], tcfg.act_alpha_pct)
        for xb, yb in data.batches(x_tr, y_tr, tcfg.batch_size,
                                   seed=tcfg.seed + epoch):
            params, vel, loss, acc = step_fn(
                params, qstates, vel, (jnp.asarray(xb), jnp.asarray(yb)), it)
            if it % tcfg.log_every == 0:
                res.history.append((it, float(loss), float(acc)))
                if verbose:
                    print(f"  step {it:5d} loss {float(loss):.4f} acc {float(acc):.3f}")
            it += 1

    res.params, res.qstates = params, qstates
    res.train_seconds = time.time() - t0
    res.eval_acc = evaluate(model, model_cfg, params, qstates,
                            test_set[0], test_set[1], quant)
    return res


def _calibrate_act(model, cfg, params, qstates, x_probe, pct: float) -> dict:
    """Per-layer activation clips from a calibration forward pass.

    Runs one unjitted forward with layers._CALIB armed; fake_quant_act
    records the 99.5th percentile of each quantized layer's input magnitude
    (keyed by qstate identity), which becomes that layer's a_alpha."""
    from . import layers as L

    L._CALIB = {}
    try:
        model.apply(params, qstates, x_probe, cfg, train=False, quant=True)
        stats = L._CALIB
    finally:
        L._CALIB = None
    out = {}
    for name, q in qstates.items():
        a = stats.get(id(q), 0.0)
        out[name] = dict(q, a_alpha=jnp.asarray(max(a, 1e-2), jnp.float32))
    return out
