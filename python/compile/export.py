"""Inference export: BN folding, graph program, binary weights, HLO lowering.

The deployment pipeline (what `make artifacts` ships to the Rust runtime):

1. **Fold** every BatchNorm into its preceding conv (`bn_fold`), producing a
   flat list of conv/linear layers with biases.
2. **Re-assign** schemes/alphas on the folded weights (folding rescales rows,
   so per-row alphas and the variance split are recomputed — same Alg. 1
   machinery).
3. **Emit**:
   * ``model.hlo.txt``   — the quantized folded forward lowered via the L1
     Pallas kernels (interpret mode -> plain HLO), loadable by the xla crate.
   * ``weights.bin``     — integer-ready weights/schemes/alphas for the Rust
     integer executor (format below).
   * ``manifest.json``   — graph program + layer table + shapes + ratio.
   * ``model.rmsa``      — the packed artifact: pre-quantized, class-sorted
     planes the Rust runtime maps and aliases with zero copies
     (``write_rmsa``; byte layout in ``rust/src/model/artifact.rs``).

The graph *program* is a tiny SSA-ish op list (conv / linear / add / gap)
interpreted identically by ``infer_folded`` here (for HLO lowering and
parity tests) and by ``rust/src/model/graph.rs`` (integer path).

``weights.bin`` layout (little-endian):
    magic   b"RMSW"  u32 version=1  u32 n_layers
    per layer:
      u32 name_len, name bytes (utf-8)
      u8  kind (0=conv 1=linear)   u8 relu_after (unused, 0)
      u32 rows, cols               # quantization view (rows = filters)
      u32 out_ch in_ch kh kw stride pad groups   # conv only (else zeros)
      f32 a_alpha
      rows * u8   scheme codes
      rows * f32  alpha
      rows * f32  bias
      rows*cols * f32 weights (row-major, folded)
"""

from __future__ import annotations

import struct

import jax
import jax.numpy as jnp
import numpy as np

from . import assignment, layers as L
from .kernels import ref
from .models import module_for


# ---------------------------------------------------------------------------
# Folding: model params -> flat layer dicts + graph program.
# ---------------------------------------------------------------------------
def _folded_conv(name, conv_p, bn_p, stride, groups=1):
    if bn_p is not None:
        f = L.bn_fold(conv_p, bn_p)
        w, b = f["w"], f["b"]
    else:
        w = conv_p["w"]
        b = conv_p.get("b", jnp.zeros((w.shape[0],), jnp.float32))
    return {
        "name": name, "kind": "conv", "w": w, "b": b,
        "stride": stride, "pad": (w.shape[-1] - 1) // 2, "groups": groups,
    }


def _folded_linear(name, p):
    return {"name": name, "kind": "linear", "w": p["w"], "b": p["b"],
            "stride": 0, "pad": 0, "groups": 1}


def fold_resnet(params, cfg):
    """Returns (layers: [dict], program: [op dict])."""
    lys, prog = [], []
    lys.append(_folded_conv("stem", params["stem"], params["bn_stem"], 1))
    prog.append({"op": "conv", "layer": "stem", "in": "in0", "out": "t", "relu": True})
    t = 0  # running buffer id; ops read/write names "b{t}"

    def buf(i):
        return f"b{i}"

    prog[-1]["in"], prog[-1]["out"] = "in0", buf(0)
    for s, n in enumerate(cfg["blocks"]):
        for b in range(n):
            name = f"s{s}b{b}"
            stride = 2 if (b == 0 and s > 0) else 1
            p = params[name]
            inp = buf(t)
            if cfg["bottleneck"]:
                lys.append(_folded_conv(f"{name}.conv1", p["conv1"], p["bn1"], 1))
                prog.append({"op": "conv", "layer": f"{name}.conv1", "in": inp, "out": buf(t + 1), "relu": True})
                lys.append(_folded_conv(f"{name}.conv2", p["conv2"], p["bn2"], stride))
                prog.append({"op": "conv", "layer": f"{name}.conv2", "in": buf(t + 1), "out": buf(t + 2), "relu": True})
                lys.append(_folded_conv(f"{name}.conv3", p["conv3"], p["bn3"], 1))
                prog.append({"op": "conv", "layer": f"{name}.conv3", "in": buf(t + 2), "out": buf(t + 3), "relu": False})
                t += 3
            else:
                lys.append(_folded_conv(f"{name}.conv1", p["conv1"], p["bn1"], stride))
                prog.append({"op": "conv", "layer": f"{name}.conv1", "in": inp, "out": buf(t + 1), "relu": True})
                lys.append(_folded_conv(f"{name}.conv2", p["conv2"], p["bn2"], 1))
                prog.append({"op": "conv", "layer": f"{name}.conv2", "in": buf(t + 1), "out": buf(t + 2), "relu": False})
                t += 2
            main_out = buf(t)  # output of the block's main branch
            if "down" in p:
                lys.append(_folded_conv(f"{name}.down", p["down"], p["bn_down"], stride))
                prog.append({"op": "conv", "layer": f"{name}.down", "in": inp, "out": buf(t + 1), "relu": False})
                t += 1
                sc = buf(t)
            else:
                sc = inp
            assert sc != main_out, "residual branches must use distinct buffers"
            prog.append({"op": "add", "a": main_out, "b": sc, "out": buf(t + 1), "relu": True})
            t += 1
    prog.append({"op": "gap", "in": buf(t), "out": buf(t + 1)})
    t += 1
    lys.append(_folded_linear("fc", params["fc"]))
    prog.append({"op": "linear", "layer": "fc", "in": buf(t), "out": "logits"})
    return lys, prog


def fold_mobilenet(params, cfg):
    from .models.mobilenet import _block_strides

    lys, prog = [], []
    lys.append(_folded_conv("stem", params["stem"], params["bn_stem"], 1))
    prog.append({"op": "conv", "layer": "stem", "in": "in0", "out": "b0", "relu": True})
    t = 0
    # channel count of each buffer, to decide residual legality (must match
    # mobilenet.apply's `inp.shape == h.shape` rule)
    ch = {"b0": params["stem"]["w"].shape[0]}

    def buf(i):
        return f"b{i}"

    for bi, stride in enumerate(_block_strides(cfg)):
        name = f"ir{bi}"
        p = params[name]
        inp = buf(t)
        cur = inp
        if "expand" in p:
            lys.append(_folded_conv(f"{name}.expand", p["expand"], p["bn_e"], 1))
            prog.append({"op": "conv", "layer": f"{name}.expand", "in": cur, "out": buf(t + 1), "relu": True})
            t += 1
            cur = buf(t)
            ch[cur] = p["expand"]["w"].shape[0]
        mid = p["dw"]["w"].shape[0]
        lys.append(_folded_conv(f"{name}.dw", p["dw"], p["bn_d"], stride, groups=mid))
        prog.append({"op": "conv", "layer": f"{name}.dw", "in": cur, "out": buf(t + 1), "relu": True})
        t += 1
        ch[buf(t)] = mid
        lys.append(_folded_conv(f"{name}.project", p["project"], p["bn_p"], 1))
        prog.append({"op": "conv", "layer": f"{name}.project", "in": buf(t), "out": buf(t + 1), "relu": False})
        t += 1
        out_ch = p["project"]["w"].shape[0]
        ch[buf(t)] = out_ch
        if stride == 1 and ch[inp] == out_ch:
            prog.append({"op": "add", "a": buf(t), "b": inp, "out": buf(t + 1), "relu": False})
            t += 1
            ch[buf(t)] = out_ch
    lys.append(_folded_conv("head", params["head"], params["bn_head"], 1))
    prog.append({"op": "conv", "layer": "head", "in": buf(t), "out": buf(t + 1), "relu": True})
    t += 1
    prog.append({"op": "gap", "in": buf(t), "out": buf(t + 1)})
    t += 1
    lys.append(_folded_linear("fc", params["fc"]))
    prog.append({"op": "linear", "layer": "fc", "in": buf(t), "out": "logits"})
    return lys, prog


def fold_model(params, cfg):
    if cfg["arch"] == "resnet":
        return fold_resnet(params, cfg)
    if cfg["arch"] == "mobilenet":
        return fold_mobilenet(params, cfg)
    raise ValueError(f"no folded export for arch {cfg['arch']!r}")


# ---------------------------------------------------------------------------
# Assignment on folded weights.
# ---------------------------------------------------------------------------
def folded_views(lys):
    return {l["name"]: l["w"].reshape(l["w"].shape[0], -1) for l in lys}


def assign_folded(lys, ratio, eigens=None, nonlinear=ref.POT_W4A4):
    """Attach scheme/alpha per layer dict (in place) and return them."""
    views = folded_views(lys)
    schemes = assignment.assign_model(views, ratio, eigens, nonlinear)
    for l in lys:
        v = views[l["name"]]
        l["scheme"] = schemes[l["name"]]
        l["alpha"] = np.asarray(ref.default_alpha(v, axis=1))
        l.setdefault("a_alpha", 4.0)
    return schemes


def calibrate_folded(lys, prog, x_probe, pct=99.5):
    """Set per-layer a_alpha from a float forward of the folded graph."""
    bufs = {"in0": jnp.asarray(x_probe)}
    by_name = {l["name"]: l for l in lys}
    for op in prog:
        if op["op"] in ("conv", "linear"):
            l = by_name[op["layer"]]
            x = bufs[op["in"]]
            l["a_alpha"] = float(np.percentile(np.abs(np.asarray(x)), pct))
            y = _float_layer(l, x)
            if op.get("relu"):
                y = jax.nn.relu(y)
            bufs[op["out"]] = y
        elif op["op"] == "add":
            y = bufs[op["a"]] + bufs[op["b"]]
            if op.get("relu"):
                y = jax.nn.relu(y)
            bufs[op["out"]] = y
        elif op["op"] == "gap":
            bufs[op["out"]] = jnp.mean(bufs[op["in"]], axis=(2, 3))
    return bufs["logits"]


def _float_layer(l, x):
    if l["kind"] == "conv":
        y = jax.lax.conv_general_dilated(
            x, l["w"], (l["stride"], l["stride"]),
            [(l["pad"], l["pad"])] * 2,
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
            feature_group_count=l["groups"])
        return y + l["b"][None, :, None, None]
    return x @ l["w"].T + l["b"]


# ---------------------------------------------------------------------------
# Quantized folded forward (the graph the HLO artifact contains).
# ---------------------------------------------------------------------------
def infer_folded(lys, prog, x, use_pallas: bool = False, act_bits: int = 4):
    """Quantized inference over the folded graph — the exact computation the
    Rust integer executor performs, expressed in jnp/Pallas for lowering."""
    from .kernels import quantizers as qz

    by_name = {l["name"]: l for l in lys}
    bufs = {"in0": x}
    for op in prog:
        if op["op"] in ("conv", "linear"):
            l = by_name[op["layer"]]
            xin = bufs[op["in"]]
            w = l["w"]
            rows = w.shape[0]
            w2d = w.reshape(rows, -1)
            alpha = jnp.asarray(l["alpha"])
            scheme = jnp.asarray(l["scheme"])
            a_alpha = float(l["a_alpha"])
            if use_pallas:
                wq2d = qz.rowwise_quant(w2d, alpha, scheme)
            else:
                wq2d = ref.rowwise_quant(w2d, alpha, scheme)
            if l["kind"] == "conv":
                if use_pallas:
                    xq = _act_quant_nchw_pallas(xin, a_alpha, act_bits)
                else:
                    xq = ref.act_quant(xin, a_alpha, act_bits)
                y = jax.lax.conv_general_dilated(
                    xq, wq2d.reshape(w.shape), (l["stride"], l["stride"]),
                    [(l["pad"], l["pad"])] * 2,
                    dimension_numbers=("NCHW", "OIHW", "NCHW"),
                    feature_group_count=l["groups"])
                y = y + l["b"][None, :, None, None]
            else:
                if use_pallas:
                    xq = qz.act_quant(xin, a_alpha, act_bits)
                else:
                    xq = ref.act_quant(xin, a_alpha, act_bits)
                # dot_general (contract dim 1 vs 1) instead of `@ wq2d.T`:
                # the transpose form lowers with a non-default {0,1} layout
                # that xla_extension 0.5.1 mis-executes (see DESIGN.md).
                y = jax.lax.dot_general(
                    xq, wq2d, (((1,), (1,)), ((), ()))) + l["b"]
            if op.get("relu"):
                y = jax.nn.relu(y)
            bufs[op["out"]] = y
        elif op["op"] == "add":
            y = bufs[op["a"]] + bufs[op["b"]]
            if op.get("relu"):
                y = jax.nn.relu(y)
            bufs[op["out"]] = y
        elif op["op"] == "gap":
            bufs[op["out"]] = jnp.mean(bufs[op["in"]], axis=(2, 3))
        else:
            raise ValueError(f"unknown op {op['op']!r}")
    return bufs["logits"]


def _act_quant_nchw_pallas(x, alpha, bits):
    from .kernels import quantizers as qz

    n, c, h, w = x.shape
    return qz.act_quant(x.reshape(n, c * h * w), alpha, bits).reshape(x.shape)


# ---------------------------------------------------------------------------
# Binary weights writer.
# ---------------------------------------------------------------------------
def write_weights_bin(path, lys):
    with open(path, "wb") as f:
        f.write(b"RMSW")
        f.write(struct.pack("<II", 1, len(lys)))
        for l in lys:
            name = l["name"].encode()
            w = np.asarray(l["w"], np.float32)
            rows = w.shape[0]
            w2d = w.reshape(rows, -1)
            kind = 0 if l["kind"] == "conv" else 1
            f.write(struct.pack("<I", len(name)))
            f.write(name)
            f.write(struct.pack("<BB", kind, 0))
            f.write(struct.pack("<II", rows, w2d.shape[1]))
            if l["kind"] == "conv":
                oc, ic, kh, kw = w.shape
                f.write(struct.pack("<IIIIIII", oc, ic, kh, kw,
                                    l["stride"], l["pad"], l["groups"]))
            else:
                f.write(struct.pack("<IIIIIII", rows, w2d.shape[1], 1, 1, 0, 0, 1))
            f.write(struct.pack("<f", float(l["a_alpha"])))
            f.write(np.asarray(l["scheme"], np.uint8).tobytes())
            f.write(np.asarray(l["alpha"], np.float32).tobytes())
            f.write(np.asarray(l["b"], np.float32).tobytes())
            f.write(w2d.astype("<f4").tobytes())


# ---------------------------------------------------------------------------
# `.rmsa` packed artifact writer (zero-copy load path).
#
# Mirrors rust/src/model/artifact.rs byte-for-byte: a 64-byte header
# (magic "RMSA", version, file length, FNV-1a-64 checksum of bytes[24:],
# layer count, section offsets), fixed 160-byte layer records, and
# 64-byte-aligned sections holding exactly what the Rust runtime keeps in
# memory — scheme codes, per-row alphas/biases, the stable class-sort
# permutation, the quantized code plane, the pre-decoded PoT multiplier
# plane, and the class-sorted kernel operand plane. Loading on the Rust
# side is then a header validation plus an mmap alias; no float parse, no
# re-quantization. The quantizer math below replicates
# rust/src/quant/{pot,fixed,apot}.rs in float32 numpy so both writers
# produce the same planes for the same folded weights.
# ---------------------------------------------------------------------------
RMSA_MAGIC = b"RMSA"
RMSA_VERSION = 1
_RMSA_ALIGN = 64
_RMSA_HEADER_LEN = 64
_RMSA_RECORD_LEN = 160


def _fnv64(payload: bytes) -> int:
    """FNV-1a-64 over LE u64 words, zero-padded tail, length mixed in —
    the artifact checksum (see `checksum` in rust/src/model/artifact.rs)."""
    prime = 0x100000001B3
    mask = (1 << 64) - 1
    h = 0xCBF29CE484222325
    n = len(payload) & ~7
    for (word,) in struct.iter_unpack("<Q", payload[:n]):
        h = ((h ^ word) * prime) & mask
    rem = payload[n:]
    if rem:
        word = int.from_bytes(rem + b"\0" * (8 - len(rem)), "little")
        h = ((h ^ word) * prime) & mask
    return ((h ^ len(payload)) * prime) & mask


def _pot_row(t):
    """PoT-4 codes + decoded multipliers for one clipped row `t = w/alpha`.

    Matches quant/pot.rs: magnitudes below half the smallest level snap to
    zero; otherwise the exponent is round-ties-even(log2) clamped to
    [-6, 0]; the storage code is sign * (1 - e) and the kernel operand is
    sign * 2^(6+e) (an i8 in [-64, 64])."""
    mag = np.abs(t)
    e = np.clip(np.round(np.log2(np.maximum(mag, np.float32(2.0 ** -10)))),
                -6.0, 0.0).astype(np.int32)
    sign = np.where(np.signbit(t), -1, 1).astype(np.int32)
    zero = mag < np.float32(2.0 ** -7)
    sign = np.where(zero, 0, sign)
    e = np.where(zero, 0, e)
    codes = (sign * (1 - e)).astype(np.int8)
    mult = (sign * np.left_shift(1, 6 + e)).astype(np.int8)
    return codes, mult


def _fixed_row(t, bits):
    """Fixed-point codes: round-ties-even(t * (2^(bits-1) - 1))."""
    n = np.float32((1 << (bits - 1)) - 1)
    return np.round(t * n).astype(np.int8)


def _apot_levels():
    """The 8 normalized APoT-4 levels (quant/apot.rs): all sums of
    {0, 1, 1/4, 1/16} x {0, 1/2}, max-normalized, sorted, deduped."""
    sums = [np.float32(a) + np.float32(b)
            for a in (0.0, 1.0, 0.25, 0.0625) for b in (0.0, 0.5)]
    top = np.float32(max(sums))
    return np.unique(np.asarray([s / top for s in sums], np.float32))


_APOT_LEVELS = _apot_levels()


def _apot_row(t):
    """APoT codes: signed index of the nearest level (first minimum wins,
    like the Rust strict-< scan; np.argmin has the same tie rule)."""
    mag = np.abs(t)
    idx = np.argmin(np.abs(mag[:, None] - _APOT_LEVELS[None, :]), axis=1)
    sign = np.where(np.signbit(t), -1, 1).astype(np.int32)
    return (sign * idx.astype(np.int32)).astype(np.int8)


def _quant_planes(w2d, scheme, alpha):
    """(codes, pot_mult) planes in model row order, as PackedWeights holds
    them: pot_mult is full-size and zero-filled outside PoT rows when any
    row is PoT, and absent (None) when none is."""
    rows, cols = w2d.shape
    codes = np.zeros((rows, cols), np.int8)
    has_pot = bool((np.asarray(scheme) == 0).any())
    mult = np.zeros((rows, cols), np.int8) if has_pot else None
    for r in range(rows):
        t = np.clip(w2d[r] / np.float32(alpha[r]), -1.0, 1.0).astype(np.float32)
        s = int(scheme[r])
        if s == 0:
            codes[r], mult[r] = _pot_row(t)
        elif s == 1:
            codes[r] = _fixed_row(t, 4)
        elif s == 2:
            codes[r] = _fixed_row(t, 8)
        elif s == 3:
            codes[r] = _apot_row(t)
        else:
            raise ValueError(f"unknown scheme code {s}")
    return codes, mult


def write_rmsa(path, lys, manifest_json: str):
    """Serialize the quantized model into one `.rmsa` artifact.

    `manifest_json` is embedded verbatim (the Rust loader parses the
    embedded copy, so the artifact is self-contained — one file is the
    whole model)."""
    out = bytearray(_RMSA_HEADER_LEN + len(lys) * _RMSA_RECORD_LEN)

    def push(sec: bytes) -> int:
        out.extend(b"\0" * (-len(out) % _RMSA_ALIGN))
        off = len(out)
        out.extend(sec)
        return off

    records = []
    for l in lys:
        w = np.asarray(l["w"], np.float32)
        rows = w.shape[0]
        w2d = w.reshape(rows, -1)
        scheme = np.asarray(l["scheme"], np.uint8)
        alpha = np.asarray(l["alpha"], np.float32)
        codes, mult = _quant_planes(w2d, scheme, alpha)
        # stable class sort == SortedWeights::from_packed's permutation
        perm = np.argsort(scheme, kind="stable").astype(np.uint32)
        ops = np.empty_like(codes)
        for sr, orig in enumerate(perm):
            ops[sr] = mult[orig] if scheme[orig] == 0 else codes[orig]
        name = l["name"].encode()
        offs = (
            push(name),
            push(scheme.tobytes()),
            push(alpha.astype("<f4").tobytes()),
            push(np.asarray(l["b"], "<f4").tobytes()),
            push(perm.astype("<u4").tobytes()),
            push(codes.tobytes()),
            push(mult.tobytes()) if mult is not None else 0,
            push(ops.tobytes()),
        )
        records.append((l, name, w, rows, w2d.shape[1], mult is not None, offs))

    mjson = manifest_json.encode()
    manifest_off = push(mjson)

    for i, (l, name, w, rows, cols, has_pot, offs) in enumerate(records):
        r = _RMSA_HEADER_LEN + i * _RMSA_RECORD_LEN
        name_off, scheme_off, alpha_off, bias_off, perm_off, codes_off, \
            pot_off, ops_off = offs
        struct.pack_into("<QI", out, r, name_off, len(name))
        out[r + 12] = 0 if l["kind"] == "conv" else 1
        out[r + 13] = 1 if has_pot else 0
        if l["kind"] == "conv":
            oc, ic, kh, kw = w.shape
            geo = (rows, cols, oc, ic, kh, kw,
                   l["stride"], l["pad"], l["groups"])
        else:
            geo = (rows, cols, rows, cols, 1, 1, 0, 0, 1)
        struct.pack_into("<9I", out, r + 16, *geo)
        struct.pack_into("<f", out, r + 52, float(l["a_alpha"]))
        struct.pack_into("<7Q", out, r + 56, scheme_off, alpha_off,
                         bias_off, perm_off, codes_off, pot_off, ops_off)

    out[0:4] = RMSA_MAGIC
    struct.pack_into("<I", out, 4, RMSA_VERSION)
    struct.pack_into("<Q", out, 8, len(out))
    struct.pack_into("<II", out, 24, len(lys), 0)
    struct.pack_into("<QQQ", out, 32, _RMSA_HEADER_LEN, manifest_off,
                     len(mjson))
    struct.pack_into("<Q", out, 16, _fnv64(bytes(out[24:])))
    with open(path, "wb") as f:
        f.write(out)


def manifest_dict(cfg, lys, prog, ratio, input_shape):
    import json as _json

    return {
        "model": cfg["name"],
        "arch": cfg["arch"],
        "num_classes": cfg["num_classes"],
        "input_shape": list(input_shape),
        "ratio": list(ratio),
        "act_bits": 4,
        "layers": [
            {
                "name": l["name"], "kind": l["kind"],
                "rows": int(l["w"].shape[0]),
                "cols": int(np.prod(l["w"].shape[1:])),
                "stride": l["stride"], "pad": l["pad"], "groups": l["groups"],
                "a_alpha": float(l["a_alpha"]),
                "scheme_counts": _counts(l["scheme"]),
            }
            for l in lys
        ],
        "program": prog,
    }


def _counts(scheme):
    s = np.asarray(scheme)
    return [int((s == i).sum()) for i in range(4)]


# ---------------------------------------------------------------------------
# HLO text lowering (the gotcha-aware path; see /opt/xla-example/README.md).
# ---------------------------------------------------------------------------
def to_hlo_text(fn, *example_args) -> str:
    """Lower a jax function to HLO text via stablehlo -> XlaComputation.

    HLO *text* (not serialized proto) is the interchange format: jax >= 0.5
    emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
    parser reassigns ids.

    `print_large_constants=True` is ESSENTIAL: the default printer elides
    big literals as ``constant({...})``, which xla_extension 0.5.1's text
    parser silently materializes as zeros — the lowered weights vanish.
    """
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text(print_large_constants=True)
