"""Training loop + dataset generators: determinism, learning signal,
assignment refresh during QAT, and dataset statistics."""

import numpy as np
import jax.numpy as jnp

from compile import assignment, data, train
from compile.models import make


def test_image_dataset_deterministic_and_bounded():
    a_x, a_y = data.image_dataset(10, n=64, seed=3)
    b_x, b_y = data.image_dataset(10, n=64, seed=3)
    np.testing.assert_array_equal(a_x, b_x)
    np.testing.assert_array_equal(a_y, b_y)
    assert a_x.min() >= 0.0 and a_x.max() < 1.0
    c_x, _ = data.image_dataset(10, n=64, seed=4)
    assert np.abs(a_x - c_x).max() > 0


def test_image_dataset_split_differs_templates_shared():
    tr_x, _ = data.image_dataset(10, n=32, seed=0, split="train")
    te_x, _ = data.image_dataset(10, n=32, seed=0, split="test")
    assert np.abs(tr_x - te_x).max() > 0  # different draws


def test_text_dataset_classes_and_determinism():
    tok, lab, nc = data.text_dataset("mnli-syn", n=128, seed=1)
    assert nc == 3
    assert tok.shape == (128, 32)
    assert set(np.unique(lab)) <= {0, 1, 2}
    tok2, lab2, _ = data.text_dataset("mnli-syn", n=128, seed=1)
    np.testing.assert_array_equal(tok, tok2)


def test_batches_cover_and_shuffle():
    x = np.arange(100)[:, None]
    y = np.arange(100)
    seen = []
    for xb, yb in data.batches(x, y, 10, seed=0):
        seen.extend(yb.tolist())
    assert len(seen) == 100
    assert sorted(seen) == list(range(100))
    assert seen != list(range(100))  # shuffled


def test_fp32_training_learns():
    cfg = make("resnet18", num_classes=4, width=8)
    tr = data.image_dataset(4, n=256, size=16, seed=0, noise=0.2)
    te = data.image_dataset(4, n=128, size=16, seed=0, split="test", noise=0.2)
    res = train.train(cfg, tr, te, train.TrainConfig(
        epochs=5, batch_size=32, use_hessian=False, log_every=10), quant=False)
    assert res.eval_acc > 0.45, f"fp32 failed to learn: {res.eval_acc}"
    assert res.history[0][1] > res.history[-1][1], "loss did not decrease"


def test_qat_refresh_applies_ratio():
    cfg = make("resnet18", num_classes=4, width=8)
    tr = data.image_dataset(4, n=128, size=16, seed=0, noise=0.2)
    te = data.image_dataset(4, n=64, size=16, seed=0, split="test", noise=0.2)
    res = train.train(cfg, tr, te, train.TrainConfig(
        epochs=1, batch_size=32, ratio=(65, 30, 5), use_hessian=False),
        quant=True)
    hist = assignment.scheme_histogram(res.qstates)
    for name, (na, nb, nc) in hist.items():
        rows = na + nb + nc
        want = assignment.ratio_counts(rows, (65, 30, 5))
        assert (na, nb, nc) == want, f"{name}: {(na, nb, nc)} != {want}"
    # activation clips were calibrated (not the default 4.0 everywhere)
    alphas = {float(q["a_alpha"]) for q in res.qstates.values()}
    assert len(alphas) > 1


def test_qat_with_hessian_runs():
    cfg = make("resnet18", num_classes=4, width=8)
    tr = data.image_dataset(4, n=64, size=16, seed=0, noise=0.2)
    te = data.image_dataset(4, n=32, size=16, seed=0, split="test", noise=0.2)
    res = train.train(cfg, tr, te, train.TrainConfig(
        epochs=1, batch_size=32, ratio=(60, 35, 5), use_hessian=True,
        hessian_iters=2, hessian_batch=16), quant=True)
    assert np.isfinite(res.eval_acc)


def test_train_deterministic():
    cfg = make("resnet18", num_classes=4, width=8)
    tr = data.image_dataset(4, n=64, size=16, seed=0)
    te = data.image_dataset(4, n=32, size=16, seed=0, split="test")
    tcfg = train.TrainConfig(epochs=1, batch_size=16, use_hessian=False, seed=7)
    a = train.train(cfg, tr, te, tcfg, quant=True)
    b = train.train(cfg, tr, te, tcfg, quant=True)
    assert a.eval_acc == b.eval_acc
    np.testing.assert_allclose(np.asarray(a.params["stem"]["w"]),
                               np.asarray(b.params["stem"]["w"]), atol=0)
