"""Assignment engine (Alg. 1 lines 2-14): ratio exactness, Hessian/variance
routing, equivalent-precision accounting."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import assignment
from compile.kernels import ref

seeds = st.integers(min_value=0, max_value=2**31 - 1)


def _ratio(a, c):
    return (a, 100 - a - c, c)


@settings(max_examples=40, deadline=None)
@given(seed=seeds, rows=st.integers(min_value=1, max_value=300),
       a=st.integers(min_value=0, max_value=100),
       c=st.integers(min_value=0, max_value=20))
def test_ratio_counts_sum_and_match(seed, rows, a, c):
    c = min(c, 100 - a)
    na, nb, nc = assignment.ratio_counts(rows, _ratio(a, c))
    assert na + nb + nc == rows
    # largest-remainder: each count within 1 of the exact share
    for n, share in ((na, a), (nb, 100 - a - c), (nc, c)):
        assert abs(n - rows * share / 100) <= 1


@settings(max_examples=25, deadline=None)
@given(seed=seeds, rows=st.integers(min_value=1, max_value=120))
def test_assign_layer_counts_exact(seed, rows):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(rows, 16)).astype(np.float32)
    scheme = assignment.assign_layer(w, (65, 30, 5))
    na, nb, nc = assignment.ratio_counts(rows, (65, 30, 5))
    assert (scheme == ref.POT_W4A4).sum() == na
    assert (scheme == ref.FIXED_W4A4).sum() == nb
    assert (scheme == ref.FIXED_W8A4).sum() == nc


def test_hessian_rows_win_high_precision():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(20, 8)).astype(np.float32)
    eigen = np.zeros(20, np.float32)
    eigen[[3, 17]] = 10.0
    scheme = assignment.assign_layer(w, (50, 40, 10), eigen=eigen)
    assert scheme[3] == ref.FIXED_W8A4
    assert scheme[17] == ref.FIXED_W8A4


def test_low_variance_rows_become_pot():
    rng = np.random.default_rng(1)
    w = rng.normal(size=(10, 32)).astype(np.float32)
    w[4] = 0.3  # zero-variance row
    scheme = assignment.assign_layer(w, (30, 70, 0))
    assert scheme[4] == ref.POT_W4A4


def test_nonlinear_override_apot():
    rng = np.random.default_rng(2)
    w = rng.normal(size=(10, 8)).astype(np.float32)
    scheme = assignment.assign_layer(w, (60, 40, 0), nonlinear=ref.APOT_W4A4)
    assert (scheme == ref.APOT_W4A4).sum() == 6
    assert (scheme == ref.POT_W4A4).sum() == 0


def test_update_qstates_refreshes_alpha_and_scheme():
    rng = np.random.default_rng(3)
    views = {"l1": jnp.asarray(rng.normal(size=(12, 9)).astype(np.float32))}
    qstates = {"l1": {"scheme": jnp.zeros(12, jnp.int32),
                      "w_alpha": jnp.ones(12), "a_alpha": jnp.asarray(1.0)}}
    new = assignment.update_qstates(qstates, views, (0, 95, 5))
    assert int((np.asarray(new["l1"]["scheme"]) == ref.FIXED_W8A4).sum()) == 1
    np.testing.assert_allclose(
        np.asarray(new["l1"]["w_alpha"]),
        np.abs(np.asarray(views["l1"])).max(axis=1), rtol=1e-6)


def test_equivalent_bits():
    qs = {"l": {"scheme": jnp.asarray([0, 1, 2, 1], jnp.int32)}}
    # (4+4+8+4)/4 = 5
    assert assignment.equivalent_bits(qs) == 5.0


def test_scheme_histogram():
    qs = {"l": {"scheme": jnp.asarray([0, 0, 1, 2], jnp.int32)}}
    assert assignment.scheme_histogram(qs)["l"] == (2, 1, 1)
