"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, bit-widths, scheme mixes, and scale ranges; every
kernel must agree with its oracle to float32 round-off. This is the core
correctness signal for the AOT pipeline — the same kernel code is lowered
into the HLO artifacts the Rust runtime executes.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import quantizers as qz
from compile.kernels import rowwise_gemm as rg

ATOL = 1e-5

dims = st.integers(min_value=1, max_value=97)
small_dims = st.integers(min_value=1, max_value=33)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def _mat(seed, rows, cols, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.normal(size=(rows, cols)) * scale).astype(np.float32))


def _rows_meta(seed, rows):
    rng = np.random.default_rng(seed + 1)
    alpha = jnp.asarray(rng.uniform(0.05, 3.0, size=rows).astype(np.float32))
    scheme = jnp.asarray(rng.integers(0, 3, size=rows).astype(np.int32))
    return alpha, scheme


@settings(max_examples=25, deadline=None)
@given(seed=seeds, rows=dims, cols=dims, m=st.sampled_from([2, 3, 4, 8]))
def test_fixed_quant_matches_ref(seed, rows, cols, m):
    w = _mat(seed, rows, cols)
    alpha, _ = _rows_meta(seed, rows)
    got = qz.fixed_quant(w, alpha, m)
    want = ref.fixed_quant(w, alpha[:, None], m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(seed=seeds, rows=dims, cols=dims, m=st.sampled_from([3, 4, 5]))
def test_pot_quant_matches_ref(seed, rows, cols, m):
    w = _mat(seed, rows, cols)
    alpha, _ = _rows_meta(seed, rows)
    got = qz.pot_quant(w, alpha, m)
    want = ref.pot_quant(w, alpha[:, None], m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(seed=seeds, rows=dims, cols=dims)
def test_rowwise_quant_matches_ref(seed, rows, cols):
    w = _mat(seed, rows, cols)
    alpha, scheme = _rows_meta(seed, rows)
    got = qz.rowwise_quant(w, alpha, scheme)
    want = ref.rowwise_quant(w, alpha, scheme)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(seed=seeds, r=dims, c=dims, m=st.sampled_from([4, 8]),
       alpha=st.floats(min_value=0.1, max_value=8.0))
def test_act_quant_matches_ref(seed, r, c, m, alpha):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-1, 2 * alpha, size=(r, c)).astype(np.float32))
    got = qz.act_quant(x, alpha, m)
    want = ref.act_quant(x, alpha, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


def test_act_quant_3d_shape():
    x = jnp.ones((2, 5, 7), jnp.float32) * 0.3
    got = qz.act_quant(x, 1.0, 4)
    assert got.shape == (2, 5, 7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.act_quant(x, 1.0, 4)), atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(seed=seeds, batch=small_dims, rows=small_dims, cols=dims,
       act_alpha=st.floats(min_value=0.2, max_value=4.0))
def test_mixed_gemm_matches_ref(seed, batch, rows, cols, act_alpha):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(0, 2, size=(batch, cols)).astype(np.float32))
    w = _mat(seed + 7, rows, cols)
    alpha, scheme = _rows_meta(seed, rows)
    got = rg.rowwise_mixed_gemm(x, w, alpha, scheme, act_alpha)
    want = ref.rowwise_mixed_gemm(x, w, alpha, scheme, act_alpha)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-4)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 16), (16, 32, 32), (128, 128, 256)])
def test_mixed_gemm_block_shapes(bm, bn, bk):
    """Result must be independent of the BlockSpec tiling."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.uniform(0, 1, size=(19, 41)).astype(np.float32))
    w = _mat(11, 23, 41)
    alpha, scheme = _rows_meta(5, 23)
    want = ref.rowwise_mixed_gemm(x, w, alpha, scheme, 1.0)
    got = rg.rowwise_mixed_gemm(x, w, alpha, scheme, 1.0,
                                block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-4)


def test_mixed_gemm_all_single_scheme_reduces_to_plain():
    """With all rows Fixed-4, the mixed GEMM equals act_quant(x) @ fixed(w)^T."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.uniform(0, 1, size=(7, 31)).astype(np.float32))
    w = _mat(13, 11, 31)
    alpha = ref.default_alpha(w, axis=1)
    scheme = jnp.full((11,), ref.FIXED_W4A4, jnp.int32)
    got = rg.rowwise_mixed_gemm(x, w, alpha, scheme, 1.0)
    want = ref.act_quant(x, 1.0, 4) @ ref.fixed_quant(w, alpha[:, None], 4).T
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


def test_vmem_budget():
    """Default block shapes stay well inside a TPU core's 16 MiB VMEM."""
    assert rg.vmem_bytes(128, 128, 256) < 16 * 2**20 // 4


def test_mxu_utilization_perfect_tiles():
    assert rg.mxu_utilization_estimate(128, 128, 256) == pytest.approx(1.0)
    assert rg.mxu_utilization_estimate(1, 1, 1) < 1e-4
