"""Model zoo: shapes, quantized-vs-float divergence bounds, weight-view
consistency, BN state flow, and gradient flow through the STE."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import data, layers as L
from compile.models import bert, mobilenet, resnet, make, module_for


@pytest.fixture(scope="module")
def image_batch():
    x, y = data.image_dataset(10, n=8, size=32, seed=0)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("name", ["resnet18", "resnet50", "mobilenetv2"])
def test_image_model_shapes_and_views(name, image_batch):
    cfg = make(name, num_classes=10)
    model = module_for(cfg)
    params, qstates = model.init(jax.random.PRNGKey(0), cfg)
    logits, newp = model.apply(params, qstates, image_batch[0], cfg, train=True)
    assert logits.shape == (8, 10)
    assert np.isfinite(np.asarray(logits)).all()
    views = model.quantized_weight_views(params, cfg)
    assert set(views) == set(qstates), "views and qstates must cover the same layers"
    for lname, v in views.items():
        assert v.ndim == 2
        assert v.shape[0] == qstates[lname]["scheme"].shape[0]


def test_bert_shapes_and_views():
    cfg = make("tinybert", num_classes=3)
    params, qstates = bert.init(jax.random.PRNGKey(0), cfg)
    tok = jnp.zeros((4, 32), jnp.int32)
    logits, _ = bert.apply(params, qstates, tok, cfg)
    assert logits.shape == (4, 3)
    views = bert.quantized_weight_views(params, cfg)
    assert set(views) == set(qstates)


def test_quantized_close_to_float_at_init(image_batch):
    """With calibrated alphas, W4A4 logits stay within a bounded distance
    of the float logits (quantization is a perturbation, not a rewrite)."""
    cfg = make("resnet18", num_classes=10)
    params, qstates = resnet.init(jax.random.PRNGKey(1), cfg)
    # refresh per-row weight clips + activation clips so the comparison is
    # meaningful (default qstates have w_alpha = 1, not max|w|)
    from compile import assignment
    from compile.train import _calibrate_act

    views = resnet.quantized_weight_views(params, cfg)
    qstates = assignment.update_qstates(qstates, views, (65, 30, 5))
    qstates = _calibrate_act(resnet, cfg, params, qstates, image_batch[0], 99.5)
    lq, _ = resnet.apply(params, qstates, image_batch[0], cfg, train=False, quant=True)
    lf, _ = resnet.apply(params, qstates, image_batch[0], cfg, train=False, quant=False)
    rel = float(jnp.max(jnp.abs(lq - lf)) / (jnp.max(jnp.abs(lf)) + 1e-6))
    assert rel < 1.5, f"quantized logits diverged: rel={rel}"


def test_bn_running_stats_update_only_in_train(image_batch):
    cfg = make("resnet18", num_classes=10)
    params, qstates = resnet.init(jax.random.PRNGKey(0), cfg)
    _, p_train = resnet.apply(params, qstates, image_batch[0], cfg, train=True)
    _, p_eval = resnet.apply(params, qstates, image_batch[0], cfg, train=False)
    moved = np.abs(np.asarray(p_train["bn_stem"]["mean"])
                   - np.asarray(params["bn_stem"]["mean"])).max()
    frozen = np.abs(np.asarray(p_eval["bn_stem"]["mean"])
                    - np.asarray(params["bn_stem"]["mean"])).max()
    assert moved > 0.0
    assert frozen == 0.0


def test_ste_gradients_flow(image_batch):
    """d loss / d weights must be nonzero through the fake quantizers."""
    cfg = make("resnet18", num_classes=10)
    params, qstates = resnet.init(jax.random.PRNGKey(0), cfg)
    x, y = image_batch

    def loss(p):
        logits, _ = resnet.apply(p, qstates, x, cfg, train=True, quant=True)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    g = jax.grad(loss)(params)
    gnorm = float(jnp.linalg.norm(g["stem"]["w"]))
    assert np.isfinite(gnorm) and gnorm > 0, f"no gradient through STE: {gnorm}"
    # BN running stats should receive no gradient contribution of use
    assert float(jnp.linalg.norm(g["fc"]["w"])) > 0


def test_mobilenet_depthwise_groups():
    cfg = mobilenet.config(num_classes=10)
    params, qstates = mobilenet.init(jax.random.PRNGKey(0), cfg)
    # depthwise conv weights are (ch, 1, 3, 3)
    assert params["ir0"]["dw"]["w"].shape[1] == 1
    x = jnp.ones((2, 3, 32, 32), jnp.float32) * 0.4
    logits, _ = mobilenet.apply(params, qstates, x, cfg, train=False)
    assert logits.shape == (2, 10)


def test_bn_fold_equivalence():
    """conv+BN (eval mode) == folded conv for arbitrary stats."""
    rng = jax.random.PRNGKey(3)
    conv = L.conv_init(rng, 3, 8, 3)
    bn = L.bn_init(8)
    bn["mean"] = jnp.linspace(-0.5, 0.5, 8)
    bn["var"] = jnp.linspace(0.5, 2.0, 8)
    bn["gamma"] = jnp.linspace(0.8, 1.2, 8)
    bn["beta"] = jnp.linspace(-0.1, 0.1, 8)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 3, 16, 16))
    y_ref, _ = L.bn_apply(bn, L.conv_apply(conv, x), train=False)
    folded = L.bn_fold(conv, bn)
    y_fold = L.conv_apply({"w": folded["w"]}, x) + folded["b"][None, :, None, None]
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_fold),
                               rtol=1e-4, atol=1e-5)
