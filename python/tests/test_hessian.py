"""Hessian sensitivity estimators (Eq. 7-8): exactness on a quadratic with
known spectrum, and ranking agreement between the exact per-filter power
iteration (Alg. 1) and the fast Hutchinson block-trace estimator the
training loop uses."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import hessian


def _quadratic_loss(diag):
    """loss(params) = 0.5 * sum_i d_i * w_i^2 — Hessian is diag(d)."""
    d = jnp.asarray(diag)

    def loss(params, batch):
        w = params["layer"]["w"]
        return 0.5 * jnp.sum(d * w * w)

    return loss


def test_power_iteration_exact_on_quadratic():
    # 3 filters x 4 weights; per-filter block Hessian is diagonal with max
    # eigenvalue = max over that filter's d entries.
    diag = np.array([[1.0, 2.0, 3.0, 0.5],
                     [9.0, 0.1, 0.2, 0.3],
                     [4.0, 4.0, 4.0, 4.0]], np.float32)
    loss = _quadratic_loss(diag)
    params = {"layer": {"w": jnp.ones((3, 4), jnp.float32)}}
    lam = hessian.filter_max_eigenvalues(loss, params, ("layer", "w"), None,
                                         iters=30, seed=0)
    np.testing.assert_allclose(np.asarray(lam), [3.0, 9.0, 4.0], rtol=1e-3)


def test_block_trace_exact_on_quadratic():
    # Hutchinson trace of a diagonal block = sum of its d entries (exact in
    # expectation; Rademacher probes make v_i^2 = 1 so it's exact per probe
    # for diagonal Hessians).
    diag = np.array([[1.0, 2.0], [5.0, 3.0]], np.float32)
    loss = _quadratic_loss(diag)
    params = {"layer": {"w": jnp.ones((2, 2), jnp.float32)}}
    tr = hessian.block_trace_estimates(loss, params, {"l": ("layer", "w")},
                                       None, samples=4, seed=1)
    np.testing.assert_allclose(np.asarray(tr["l"]), [3.0, 8.0], rtol=1e-4)


def test_trace_and_power_agree_on_topk_model():
    """On a real (tiny) quantized model, the top-20% filters by block trace
    should substantially overlap the top-20% by exact max eigenvalue —
    this is the substitution the training loop makes for speed."""
    from compile import data, train
    from compile.models import resnet

    cfg = resnet.config("resnet18", num_classes=4, width=8)
    import jax as _jax

    params, qstates = resnet.init(_jax.random.PRNGKey(0), cfg)
    x, y = data.image_dataset(4, n=32, size=16, seed=0)
    batch = (jnp.asarray(x), jnp.asarray(y))
    _, loss_fn = train.make_train_step(resnet, cfg, True, train.TrainConfig(), 10)
    lf = lambda p, b: loss_fn(p, qstates, b)[0]

    layer = ("s1b0", "conv1", "w")
    lam = np.asarray(hessian.filter_max_eigenvalues(lf, params, layer, batch,
                                                    iters=10, seed=0))
    tr = np.asarray(hessian.block_trace_estimates(
        lf, params, {"l": layer}, batch, samples=16, seed=0)["l"])
    # rank agreement: Spearman correlation of the two sensitivity rankings
    # must be clearly positive (they are different functionals of the same
    # block Hessians — max eigenvalue vs trace — so exact top-k identity is
    # not expected at random init, but the orderings must align).
    def ranks(v):
        r = np.empty(len(v))
        r[np.argsort(v)] = np.arange(len(v))
        return r
    rl, rt = ranks(lam), ranks(tr)
    rho = np.corrcoef(rl, rt)[0, 1]
    assert rho > 0.3, f"rank correlation {rho} (lam={lam}, tr={tr})"


def test_trace_estimator_scales_with_sharpness():
    """Doubling the loss doubles every block trace (linearity sanity)."""
    diag = np.array([[1.0, 1.0], [2.0, 2.0]], np.float32)
    params = {"layer": {"w": jnp.ones((2, 2), jnp.float32)}}
    t1 = hessian.block_trace_estimates(_quadratic_loss(diag), params,
                                       {"l": ("layer", "w")}, None, samples=4)
    t2 = hessian.block_trace_estimates(_quadratic_loss(2 * diag), params,
                                       {"l": ("layer", "w")}, None, samples=4)
    np.testing.assert_allclose(2 * np.asarray(t1["l"]), np.asarray(t2["l"]),
                               rtol=1e-4)
