"""Property tests on the quantizer oracles themselves (ref.py).

These pin down the *mathematical* invariants the paper relies on:
idempotence, level membership, symmetry, scale equivariance, the PoT rigid
resolution phenomenon, and APoT's tail-density advantage. The Rust
implementations are held to the same invariants via shared test vectors.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

seeds = st.integers(min_value=0, max_value=2**31 - 1)
bits = st.sampled_from([3, 4, 5, 8])


def _w(seed, n=64, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.normal(size=(n,)) * scale).astype(np.float32))


# ---------------------------------------------------------------------------
# Idempotence: quantizing a quantized tensor is the identity.
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(seed=seeds, m=bits, alpha=st.floats(min_value=0.1, max_value=4.0))
def test_fixed_idempotent(seed, m, alpha):
    w = _w(seed)
    q1 = ref.fixed_quant(w, alpha, m)
    q2 = ref.fixed_quant(q1, alpha, m)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(seed=seeds, m=st.sampled_from([3, 4, 5]), alpha=st.floats(min_value=0.1, max_value=4.0))
def test_pot_idempotent(seed, m, alpha):
    w = _w(seed)
    q1 = ref.pot_quant(w, alpha, m)
    q2 = ref.pot_quant(q1, alpha, m)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-6)


# ---------------------------------------------------------------------------
# Level membership: outputs land exactly on ±alpha * levels.
# ---------------------------------------------------------------------------
def _assert_on_levels(q, alpha, levels, atol=1e-6):
    q = np.abs(np.asarray(q)) / alpha
    lv = np.asarray(levels)
    d = np.min(np.abs(q[:, None] - lv[None, :]), axis=1)
    assert d.max() < atol, f"value off-grid by {d.max()}"


@settings(max_examples=20, deadline=None)
@given(seed=seeds, m=bits)
def test_fixed_on_levels(seed, m):
    w = _w(seed, scale=2.0)
    _assert_on_levels(ref.fixed_quant(w, 1.3, m), 1.3, ref.fixed_levels(m))


@settings(max_examples=20, deadline=None)
@given(seed=seeds, m=st.sampled_from([3, 4, 5]))
def test_pot_on_levels(seed, m):
    w = _w(seed, scale=2.0)
    _assert_on_levels(ref.pot_quant(w, 0.9, m), 0.9, ref.pot_levels(m))


@settings(max_examples=10, deadline=None)
@given(seed=seeds)
def test_apot_on_levels(seed):
    w = _w(seed, scale=2.0)
    _assert_on_levels(ref.apot_quant(w, 1.0, 4), 1.0, ref.apot_levels(4), atol=1e-5)


# ---------------------------------------------------------------------------
# Symmetry and scale equivariance.
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(seed=seeds, m=bits)
def test_fixed_odd_symmetry(seed, m):
    w = _w(seed)
    np.testing.assert_allclose(
        np.asarray(ref.fixed_quant(-w, 1.0, m)),
        -np.asarray(ref.fixed_quant(w, 1.0, m)), atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=seeds, m=st.sampled_from([3, 4]), c=st.floats(min_value=0.25, max_value=4.0))
def test_quant_scale_equivariance(seed, m, c):
    """Q(c*w, c*alpha) == c * Q(w, alpha) for both schemes."""
    w = _w(seed)
    for q in (ref.fixed_quant, ref.pot_quant):
        a = np.asarray(q(w * c, c * 1.1, m))
        b = c * np.asarray(q(w, 1.1, m))
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Error bounds & the rigid-resolution phenomenon (paper §1, §2.1.2).
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(seed=seeds, m=st.sampled_from([4, 8]))
def test_fixed_error_bound(seed, m):
    """|w - Q(w)| <= alpha/(2*(2^{m-1}-1)) for w inside the clip range."""
    w = jnp.clip(_w(seed), -1.0, 1.0) * 0.999
    q = ref.fixed_quant(w, 1.0, m)
    step = 1.0 / (2 ** (m - 1) - 1)
    assert np.abs(np.asarray(w - q)).max() <= step / 2 + 1e-6


def test_pot_rigid_resolution():
    """PoT error does NOT vanish with more bits (rigid resolution, §2.1.2):
    extra bits only refine near zero, the gap at e.g. 0.75 stays ~0.25/1."""
    w = jnp.asarray([0.75], jnp.float32)
    e4 = abs(float(ref.pot_quant(w, 1.0, 4)[0]) - 0.75)
    e8 = abs(float(ref.pot_quant(w, 1.0, 8)[0]) - 0.75)
    assert e4 == pytest.approx(0.25, abs=1e-6)
    assert e8 == pytest.approx(0.25, abs=1e-6)  # unchanged: rigid resolution


def test_fixed_resolution_improves_with_bits():
    w = jnp.asarray([0.75], jnp.float32)
    e4 = abs(float(ref.fixed_quant(w, 1.0, 4)[0]) - 0.75)
    e8 = abs(float(ref.fixed_quant(w, 1.0, 8)[0]) - 0.75)
    assert e8 < e4 or e4 < 1e-6


def test_apot_beats_pot_at_tails():
    """APoT levels are denser near |w|=1 than PoT (its design goal)."""
    pot = np.asarray(ref.pot_levels(4))
    apot = np.asarray(ref.apot_levels(4))
    tail = lambda lv: np.sort(lv)[-2]  # second-largest level
    assert tail(apot) > tail(pot)


@settings(max_examples=10, deadline=None)
@given(seed=seeds)
def test_mse_ordering_gaussian(seed):
    """For Gaussian rows: MSE(Fixed8) < MSE(Fixed4) < MSE(PoT4) and
    MSE(APoT4) < MSE(PoT4) — the per-scheme orderings behind Table 1
    (Fixed > APoT > PoT in accuracy; APoT fixes PoT's rigid resolution)."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray((rng.normal(size=(8192,)) * 0.5).astype(np.float32))
    a = ref.default_alpha(w)
    mse = lambda q: float(jnp.mean((w - q) ** 2))
    m_f4 = mse(ref.fixed_quant(w, a, 4))
    m_f8 = mse(ref.fixed_quant(w, a, 8))
    m_p4 = mse(ref.pot_quant(w, a, 4))
    m_a4 = mse(ref.apot_quant(w, a, 4))
    assert m_f8 < m_f4
    assert m_f4 < m_p4
    assert m_a4 < m_p4


# ---------------------------------------------------------------------------
# Variance rule sanity (paper §3.1): PoT fits low-variance rows better.
# ---------------------------------------------------------------------------
def test_pot_favours_low_variance_rows():
    """Relative MSE advantage of Fixed over PoT grows with row variance —
    the basis of the variance-threshold scheme assignment."""
    rng = np.random.default_rng(0)
    rel = []
    for s in (0.1, 0.4, 1.0):
        w = jnp.asarray((rng.normal(size=(8192,)) * s).astype(np.float32))
        a = ref.default_alpha(w)
        mse_f = float(jnp.mean((w - ref.fixed_quant(w, a, 4)) ** 2))
        mse_p = float(jnp.mean((w - ref.pot_quant(w, a, 4)) ** 2))
        rel.append(mse_p / max(mse_f, 1e-12))
    assert rel[0] <= rel[-1] * 1.5  # advantage does not shrink with variance


# ---------------------------------------------------------------------------
# Codes round-trip: integer codes reproduce the fake-quant values.
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(seed=seeds, m=st.sampled_from([4, 8]))
def test_fixed_code_roundtrip(seed, m):
    w = _w(seed)
    code = ref.fixed_quant_code(w, 1.2, m)
    n = 2 ** (m - 1) - 1
    assert int(jnp.abs(code).max()) <= n
    recon = 1.2 * code.astype(jnp.float32) / n
    np.testing.assert_allclose(np.asarray(recon),
                               np.asarray(ref.fixed_quant(w, 1.2, m)), atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=seeds)
def test_pot_code_roundtrip(seed):
    w = _w(seed)
    sign, e = ref.pot_quant_code(w, 0.8, 4)
    assert int(e.min()) >= -(2**3 - 2) and int(e.max()) <= 0
    recon = 0.8 * sign.astype(jnp.float32) * (2.0 ** e.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(recon),
                               np.asarray(ref.pot_quant(w, 0.8, 4)), atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=seeds, m=st.sampled_from([4, 8]))
def test_act_code_roundtrip(seed, m):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-0.5, 2.0, size=(256,)).astype(np.float32))
    code = ref.act_quant_code(x, 1.5, m)
    assert int(code.min()) >= 0 and int(code.max()) <= 2**m - 1
    recon = 1.5 * code.astype(jnp.float32) / (2**m - 1)
    np.testing.assert_allclose(np.asarray(recon),
                               np.asarray(ref.act_quant(x, 1.5, m)), atol=1e-6)
