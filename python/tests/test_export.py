"""Export pipeline: folding correctness, quantized graph parity between the
model apply() and the folded program, binary format round-trip, manifest
consistency, and HLO text hygiene (no elided constants)."""

import os
import struct

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import assignment, data, export
from compile.models import resnet, make


@pytest.fixture(scope="module")
def folded():
    cfg = make("resnet18", num_classes=10)
    params, qstates = resnet.init(jax.random.PRNGKey(0), cfg)
    lys, prog = export.fold_model(params, cfg)
    export.assign_folded(lys, (65, 30, 5))
    probe, _ = data.image_dataset(10, n=8, size=32, seed=0)
    export.calibrate_folded(lys, prog, probe)
    return cfg, params, qstates, lys, prog, jnp.asarray(probe)


def test_fold_covers_all_quantized_layers(folded):
    cfg, params, qstates, lys, prog, _ = folded
    names = {l["name"] for l in lys}
    assert names == set(qstates), names ^ set(qstates)


def test_folded_float_forward_matches_model_eval(folded):
    """Float folded graph == model.apply(train=False, quant=False) after BN
    folding (eval-mode BN is exactly what gets folded)."""
    cfg, params, qstates, lys, prog, x = folded
    want, _ = resnet.apply(params, qstates, x, cfg, train=False, quant=False)
    got = export.calibrate_folded(lys, prog, x)  # returns float logits
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-4)


def test_quantized_folded_graph_runs_and_is_quantized(folded):
    cfg, params, qstates, lys, prog, x = folded
    y = export.infer_folded(lys, prog, x)
    assert y.shape == (x.shape[0], 10)
    assert np.isfinite(np.asarray(y)).all()
    # pallas path == ref path
    y_p = export.infer_folded(lys, prog, x, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y), atol=1e-4)


def test_assignment_on_folded_is_ratio_exact(folded):
    cfg, params, qstates, lys, prog, _ = folded
    for l in lys:
        counts = assignment.ratio_counts(l["w"].shape[0], (65, 30, 5))
        s = np.asarray(l["scheme"])
        assert (s == 0).sum() == counts[0], l["name"]
        assert (s == 1).sum() == counts[1], l["name"]
        assert (s == 2).sum() == counts[2], l["name"]


def test_weights_bin_roundtrip(tmp_path, folded):
    cfg, params, qstates, lys, prog, _ = folded
    path = tmp_path / "weights.bin"
    export.write_weights_bin(path, lys)
    raw = path.read_bytes()
    assert raw[:4] == b"RMSW"
    version, n_layers = struct.unpack("<II", raw[4:12])
    assert version == 1
    assert n_layers == len(lys)
    # spot-check first layer record
    name_len = struct.unpack("<I", raw[12:16])[0]
    assert raw[16:16 + name_len].decode() == lys[0]["name"]


def test_rmsa_artifact_structure(tmp_path, folded):
    """The packed artifact must carry a valid header: magic, version, file
    length, the FNV checksum over bytes[24:], and 64-byte-aligned section
    offsets — the invariants the Rust loader rejects artifacts over."""
    import json

    cfg, params, qstates, lys, prog, _ = folded
    m = export.manifest_dict(cfg, lys, prog, [65, 30, 5], (8, 3, 32, 32))
    mjson = json.dumps(m)
    path = tmp_path / "model.rmsa"
    export.write_rmsa(path, lys, mjson)
    raw = path.read_bytes()
    assert raw[:4] == b"RMSA"
    version, = struct.unpack("<I", raw[4:8])
    file_len, checksum = struct.unpack("<QQ", raw[8:24])
    n_layers, flags = struct.unpack("<II", raw[24:32])
    table_off, manifest_off, manifest_len = struct.unpack("<QQQ", raw[32:56])
    assert version == 1 and flags == 0
    assert file_len == len(raw)
    assert checksum == export._fnv64(raw[24:])
    assert n_layers == len(lys) and table_off == 64
    assert manifest_off % 64 == 0
    assert raw[manifest_off:manifest_off + manifest_len].decode() == mjson
    # every section offset in every 160-byte layer record is 64-aligned,
    # and the stored permutation is the stable class sort of the schemes
    for i, l in enumerate(lys):
        r = table_off + i * 160
        name_off, name_len = struct.unpack("<QI", raw[r:r + 12])
        assert name_off % 64 == 0
        assert raw[name_off:name_off + name_len].decode() == l["name"]
        rows = struct.unpack("<I", raw[r + 16:r + 20])[0]
        assert rows == l["w"].shape[0]
        offs = struct.unpack("<7Q", raw[r + 56:r + 112])
        for off in offs:
            assert off % 64 == 0  # pot_mult may be 0 (still aligned)
        perm_off = offs[3]
        perm = np.frombuffer(raw[perm_off:perm_off + 4 * rows], "<u4")
        want = np.argsort(np.asarray(l["scheme"], np.uint8), kind="stable")
        np.testing.assert_array_equal(perm, want.astype(np.uint32))


def test_manifest_dict_schema(folded):
    cfg, params, qstates, lys, prog, _ = folded
    m = export.manifest_dict(cfg, lys, prog, [65, 30, 5], (8, 3, 32, 32))
    assert m["model"] == "resnet18"
    assert len(m["layers"]) == len(lys)
    for lm in m["layers"]:
        assert sum(lm["scheme_counts"]) == lm["rows"]
    ops = {op["op"] for op in m["program"]}
    assert ops <= {"conv", "linear", "add", "gap"}


def test_hlo_text_has_no_elided_constants(folded):
    """The xla_extension 0.5.1 text parser reads `constant({...})` as
    zeros — the gotcha that silently drops weights. Never ship one."""
    cfg, params, qstates, lys, prog, _ = folded
    spec = jax.ShapeDtypeStruct((2, 3, 32, 32), jnp.float32)
    fn = lambda x: (export.infer_folded(lys, prog, x),)
    hlo = export.to_hlo_text(fn, spec)
    assert "constant({...})" not in hlo
    assert hlo.startswith("HloModule")


def test_mobilenet_folds_too():
    cfg = make("mobilenetv2", num_classes=10)
    from compile.models import mobilenet

    params, qstates = mobilenet.init(jax.random.PRNGKey(0), cfg)
    lys, prog = export.fold_model(params, cfg)
    assert {l["name"] for l in lys} == set(qstates)
    export.assign_folded(lys, (65, 30, 5))
    probe, _ = data.image_dataset(10, n=4, size=32, seed=0)
    export.calibrate_folded(lys, prog, probe)
    y = export.infer_folded(lys, prog, jnp.asarray(probe))
    assert y.shape == (4, 10)
