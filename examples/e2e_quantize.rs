//! End-to-end driver: all layers of the stack composing on one workload.
//!
//! Pipeline exercised (after `make artifacts`, which runs the L2/L1 Python
//! side once):
//!
//!   1. load the AOT artifacts (manifest + folded weights),
//!   2. verify integer executor == recorded JAX logits on the parity
//!      vector (HLO-vs-JAX parity runs on the Python side now that the
//!      build carries no PJRT backend),
//!   3. run a 256-image synthetic batch workload through the sequential
//!      integer executor, measuring throughput,
//!   4. run the same workload through the *parallel* executor, check
//!      bit-exact agreement, and report the speedup,
//!   5. simulate the FPGA deployment of this exact model (from the
//!      manifest's layer shapes) and print the projected speedup of the
//!      RMSMP ratio vs the Fixed-only baseline.
//!
//! The run is recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `cargo run --release --example e2e_quantize`

use std::time::Instant;

use rmsmp::fpga::{simulate, Board, CoreCosts, Design, QuantConfig};
use rmsmp::model::{Executor, Manifest, ModelWeights};
use rmsmp::quant::tensor::Tensor4;
use rmsmp::quant::Ratio;
use rmsmp::runtime::{artifacts_dir, Runtime};
use rmsmp::util::json::Json;
use rmsmp::util::rng::Rng;
use rmsmp::{ensure, ParallelConfig};

fn main() -> rmsmp::Result<()> {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir.join("manifest.json"))?;
    let weights = ModelWeights::load(&dir.join("weights.bin"))?;
    let (n_in, c, h, w) = (
        manifest.input_shape[0],
        manifest.input_shape[1],
        manifest.input_shape[2],
        manifest.input_shape[3],
    );
    println!(
        "[1] loaded {}: {} layers, ratio {}, {}x{}x{} input, {:.1}x compression",
        manifest.model,
        manifest.layers.len(),
        manifest.ratio,
        c,
        h,
        w,
        weights.float_bytes() as f64 / weights.quantized_bytes() as f64,
    );

    // --- 2. integer parity vs recorded JAX logits --------------------------
    let parity = Json::load(&dir.join("parity.json"))?;
    let input = parity.get("input")?.as_f32_vec()?;
    let want = parity.get("logits")?.as_f32_vec()?;
    let mut exec = Executor::new(manifest.clone(), weights.clone())?;
    let mut x0 = Tensor4::zeros(n_in, c, h, w);
    x0.data.copy_from_slice(&input);
    let got = exec.infer(&x0)?;
    let int_err = got.data.iter().zip(&want).fold(0.0f32, |e, (a, b)| e.max((a - b).abs()));
    println!("[2] parity: integer-vs-jax {int_err:.6}");
    ensure!(int_err < 1e-3, "parity failure");

    // --- 3. sequential integer throughput workload -------------------------
    let total = 256usize;
    let batch = n_in;
    let mut rng = Rng::new(1);
    let t0 = Instant::now();
    let mut int_logits = Vec::with_capacity(total / batch);
    for _ in 0..total / batch {
        let mut x = Tensor4::zeros(batch, c, h, w);
        for v in x.data.iter_mut() {
            *v = rng.uniform(0.0, 1.0);
        }
        int_logits.push(exec.infer(&x)?.clone());
    }
    let int_dt = t0.elapsed().as_secs_f64();
    let gmacs = exec.macs as f64 / 1e9;
    println!(
        "[3] sequential: {total} images in {int_dt:.2}s ({:.1} img/s, {:.2} GMAC total)",
        total as f64 / int_dt,
        gmacs
    );

    // --- 4. parallel executor on the same workload -------------------------
    let rt = Runtime::new(ParallelConfig::default());
    let mut par = rt.executor(manifest.clone(), weights)?;
    let mut rng = Rng::new(1); // same stream
    let t1 = Instant::now();
    let mut exact = true;
    for batch_logits in &int_logits {
        let mut x = Tensor4::zeros(batch, c, h, w);
        for v in x.data.iter_mut() {
            *v = rng.uniform(0.0, 1.0);
        }
        let y = par.infer(&x)?;
        exact &= y.data == batch_logits.data;
    }
    let par_dt = t1.elapsed().as_secs_f64();
    println!(
        "[4] parallel ({} threads): {total} images in {par_dt:.2}s ({:.1} img/s, {:.2}x)",
        rt.threads(),
        total as f64 / par_dt,
        int_dt / par_dt
    );
    ensure!(exact, "parallel and sequential paths diverged");

    // --- 5. FPGA projection -------------------------------------------------
    let layers = manifest.layer_shapes();
    let rmsmp = Design::allocate(
        Board::XC7Z045,
        QuantConfig { ratio: manifest.ratio, first_last_8bit: false, apot: false },
        CoreCosts::default(),
    );
    let baseline = Design::allocate(
        Board::XC7Z045,
        QuantConfig { ratio: Ratio::new(0, 100, 0), first_last_8bit: true, apot: false },
        CoreCosts::default(),
    );
    let r1 = simulate(&rmsmp, &layers);
    let r0 = simulate(&baseline, &layers);
    println!(
        "[5] FPGA projection (XC7Z045): RMSMP {:.2} ms vs Fixed {:.2} ms -> {:.2}x speedup",
        r1.latency_ms,
        r0.latency_ms,
        r0.latency_ms / r1.latency_ms
    );
    println!("e2e OK");
    Ok(())
}
