//! Quickstart: the RMSMP public API in one file.
//!
//! 1. Build a weight matrix, assign row-wise schemes under the 65:30:5
//!    ratio (Alg. 1: sensitivity top-5% -> Fixed-8, low-variance -> PoT).
//! 2. Quantize to integer codes and run the mixed GEMM.
//! 3. Check the integer result against the float fake-quant reference.
//! 4. Size the FPGA design for the same ratio and report Table-6-style
//!    numbers.
//!
//! Run: `cargo run --release --example quickstart`

use rmsmp::assign::{assign_layer, equivalent_bits, Sensitivity};
use rmsmp::fpga::{simulate, Board, CoreCosts, Design, QuantConfig};
use rmsmp::gemm::{
    chunk_tasks, GemmActs, GemmCall, GemmOut, GemmScratch, MixedGemm, PackedActs,
    PackedWeights, SortedWeights,
};
use rmsmp::quant::{default_alpha, Mat, Ratio, Scheme};
use rmsmp::util::rng::Rng;

fn main() {
    // --- 1. a layer's weights (64 filters x 288 inputs) -------------------
    let (rows, cols) = (64, 288);
    let mut rng = Rng::new(42);
    let w = Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 0.5));

    let ratio = Ratio::RMSMP2; // 65:30:5, the paper's XC7Z045 optimum
    let schemes = assign_layer(&w, ratio, Sensitivity::WeightNorm, Scheme::PotW4A4);
    let (pot, f4, f8) = (
        schemes.iter().filter(|&&s| s == Scheme::PotW4A4).count(),
        schemes.iter().filter(|&&s| s == Scheme::FixedW4A4).count(),
        schemes.iter().filter(|&&s| s == Scheme::FixedW8A4).count(),
    );
    println!("assignment @ {ratio}: PoT-W4A4={pot} Fixed-W4A4={f4} Fixed-W8A4={f8}");
    println!("equivalent precision: {:.2} bits/weight", equivalent_bits(&schemes, cols));

    // --- 2. quantize + mixed GEMM -----------------------------------------
    let alpha: Vec<f32> = (0..rows).map(|r| default_alpha(w.row(r))).collect();
    let packed = PackedWeights::quantize(&w, &schemes, &alpha);
    println!(
        "weights: {} KiB float -> {} KiB quantized",
        4 * rows * cols / 1024,
        packed.storage_bits() / 8 / 1024
    );

    let batch = 8;
    let xd: Vec<f32> = (0..batch * cols).map(|_| rng.uniform(0.0, 1.0)).collect();
    let x = Mat::from_vec(batch, cols, xd);
    let acts = PackedActs::quantize(&x, 1.0, 4);
    let gemm = MixedGemm::new();
    // sort the rows class-contiguous once, chunk the partition into a
    // task schedule, and dispatch — the one mixed-GEMM entry point
    let sorted = SortedWeights::from_packed(&packed);
    let chunks = chunk_tasks(sorted.partition(), gemm.config().min_rows_per_task);
    let mut scratch = GemmScratch::new(gemm.lanes());
    let mut y = Mat::zeros(batch, rows);
    gemm.dispatch(
        GemmCall {
            acts: GemmActs::Packed(&acts),
            weights: &sorted,
            chunks: &chunks,
            parallel: false,
            fill: true,
            out: GemmOut::F32(&mut y),
        },
        &mut scratch,
    );

    // --- 3. verify against the float fake-quant reference -----------------
    let y_ref = gemm.run_float(&x, &w, &schemes, &alpha, 1.0, 4);
    let err = y.max_abs_err(&y_ref);
    println!("integer vs fake-quant GEMM: max |err| = {err:.6} (expect < 1e-3)");
    assert!(err < 1e-3);

    // --- 4. FPGA design for this ratio ------------------------------------
    let design = Design::allocate(
        Board::XC7Z045,
        QuantConfig { ratio, first_last_8bit: false, apot: false },
        CoreCosts::default(),
    );
    let r = simulate(&design, &rmsmp::fpga::sim::resnet18_imagenet_layers());
    println!(
        "XC7Z045 @ {ratio}: LUT {:.0}% DSP {:.0}% -> {:.1} GOP/s, {:.1} ms / image",
        100.0 * r.lut_util,
        100.0 * r.dsp_util,
        r.gops,
        r.latency_ms
    );
    println!("quickstart OK");
}
