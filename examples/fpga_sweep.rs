//! FPGA ratio sweep: find the optimal PoT:Fixed4:Fixed8 ratio per board.
//!
//! Reproduces the paper's design-space exploration behind Table 6's
//! "optimal ratio" claims (60:35:5 on XC7Z020, 65:30:5 on XC7Z045): sweep
//! the PoT share with Fixed-W8A4 pinned at 5% (paper §3.2), simulate the
//! ResNet-18/ImageNet workload, and report throughput/latency/utilization.
//!
//! Run: `cargo run --release --example fpga_sweep`

use rmsmp::fpga::{simulate, Board, CoreCosts, Design, QuantConfig};
use rmsmp::quant::Ratio;

fn main() {
    let layers = rmsmp::fpga::sim::resnet18_imagenet_layers();
    for board in [Board::XC7Z020, Board::XC7Z045] {
        println!("\n== {} ({} LUTs, {} DSPs) ==", board.name, board.luts, board.dsps);
        println!("{:>10} {:>7} {:>7} {:>12} {:>10}", "ratio", "LUT%", "DSP%", "GOP/s", "ms/img");
        let mut best: Option<(Ratio, f64)> = None;
        for pot in [0u32, 20, 35, 50, 60, 65, 70, 80, 90, 95] {
            let fixed8 = 5u32;
            let fixed4 = 100 - pot - fixed8;
            let ratio = Ratio::new(pot, fixed4, fixed8);
            let d = Design::allocate(
                board,
                QuantConfig { ratio, first_last_8bit: false, apot: false },
                CoreCosts::default(),
            );
            let r = simulate(&d, &layers);
            let rs = ratio.to_string();
            println!(
                "{rs:>10} {:>6.0}% {:>6.0}% {:>12.1} {:>10.2}",
                100.0 * r.lut_util,
                100.0 * r.dsp_util,
                r.gops,
                r.latency_ms
            );
            if best.is_none_or(|(_, g)| r.gops > g) {
                best = Some((ratio, r.gops));
            }
        }
        let (ratio, gops) = best.unwrap();
        println!("best ratio on {}: {ratio} ({gops:.1} GOP/s)", board.name);
        println!("(paper: 60:35:5 on XC7Z020, 65:30:5 on XC7Z045 — accuracy");
        println!(" constraints cap the usable PoT share; see Fig. 3 / fig3.md)");
    }
}
