//! Serving example: load the AOT artifacts, start the HTTP/1.1
//! front-end on loopback, and self-query it curl-style — the full L3
//! request path end to end (socket → lazy JSON parse → batcher →
//! compiled plan → response), with the Prometheus `/metrics` endpoint
//! printed at the end. Python never runs here.
//!
//! Run after `make artifacts`:
//!     cargo run --release --example serve_quantized [rate_rps] [n_requests]

use std::fmt::Write as _;
use std::time::{Duration, Instant};

use rmsmp::coordinator::batcher::BatchPolicy;
use rmsmp::coordinator::{HttpConfig, HttpServer, Server, ServerConfig, SimpleClient};
use rmsmp::model::{Manifest, ModelWeights};
use rmsmp::runtime::artifacts_dir;
use rmsmp::ParallelConfig;

fn main() -> rmsmp::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let rate: f64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(20.0);
    let n: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(80);

    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir.join("manifest.json"))?;
    let weights = ModelWeights::load(&dir.join("weights.bin"))?;
    println!(
        "serving {} ({} layers, ratio {}) — {n} requests at {rate} req/s over HTTP",
        manifest.model,
        manifest.layers.len(),
        manifest.ratio
    );

    let image_len = manifest.input_shape[1] * manifest.input_shape[2] * manifest.input_shape[3];
    let server = Server::start(
        manifest,
        weights,
        ServerConfig {
            workers: 1,
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(4),
                queue_cap: 512,
            },
            parallel: ParallelConfig::default(),
        },
    )?;
    let http = HttpServer::start(server, HttpConfig::default())?;
    println!("listening on http://{} — try:", http.addr());
    println!(
        "  curl -s http://{}/v1/infer -d '{{\"input\": [0.1, ...], \"deadline_ms\": 50}}'",
        http.addr()
    );
    println!("  curl -s http://{}/metrics", http.addr());

    // self-query like curl would: one keep-alive connection, POSTing
    // JSON bodies at the requested open-loop rate
    let addr = http.addr().to_string();
    let mut body = String::with_capacity(image_len * 10 + 64);
    let mut client = SimpleClient::connect(&addr)?;
    let t0 = Instant::now();
    let mut ok = 0;
    let mut shed = 0;
    for k in 0..n {
        let target = Duration::from_secs_f64(k as f64 / rate);
        if let Some(sleep) = target.checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        body.clear();
        body.push_str("{\"deadline_ms\": 250, \"input\": [");
        for i in 0..image_len {
            if i > 0 {
                body.push(',');
            }
            let _ = write!(body, "{}", ((i + k) % 29) as f32 / 29.0);
        }
        body.push_str("]}");
        let resp = client.request("POST", "/v1/infer", &body)?;
        match resp.status {
            200 => ok += 1,
            504 => shed += 1,
            s => println!("request {k}: HTTP {s} {}", resp.body.trim_end()),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("completed {ok}/{n} (shed {shed}) in {wall:.2}s ({:.1} req/s)", ok as f64 / wall);

    let metrics = client.request("GET", "/metrics", "")?;
    println!("--- GET /metrics ---");
    for line in metrics.body.lines().filter(|l| !l.starts_with('#')) {
        println!("{line}");
    }
    println!("{}", http.summary());
    http.shutdown();
    Ok(())
}
