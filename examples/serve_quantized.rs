//! Serving example: load the AOT artifacts, start the dynamic-batching
//! coordinator, drive it with an open-loop Poisson workload, and report
//! latency percentiles + throughput — the L3 request path end to end
//! (Python never runs here).
//!
//! Run after `make artifacts`:
//!     cargo run --release --example serve_quantized [rate_rps] [n_requests]

use std::time::{Duration, Instant};

use rmsmp::coordinator::batcher::BatchPolicy;
use rmsmp::coordinator::{OpenLoopGen, Server, ServerConfig};
use rmsmp::model::{Manifest, ModelWeights};
use rmsmp::runtime::artifacts_dir;
use rmsmp::ParallelConfig;

fn main() -> rmsmp::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let rate: f64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(20.0);
    let n: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(80);

    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir.join("manifest.json"))?;
    let weights = ModelWeights::load(&dir.join("weights.bin"))?;
    println!(
        "serving {} ({} layers, ratio {}) — {n} requests at {rate} req/s",
        manifest.model,
        manifest.layers.len(),
        manifest.ratio
    );

    let image_len = manifest.input_shape[1] * manifest.input_shape[2] * manifest.input_shape[3];
    let server = Server::start(
        manifest,
        weights,
        ServerConfig {
            workers: 1,
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(4),
                queue_cap: 512,
            },
            parallel: ParallelConfig::default(),
        },
    )?;

    let mut gen = OpenLoopGen::new(7, rate, image_len);
    let trace = gen.trace(n);
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(n);
    for ev in &trace {
        if let Some(sleep) = Duration::from_secs_f64(ev.at_s).checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        match server.submit(ev.image.clone()) {
            Ok(rx) => rxs.push(rx),
            Err(e) => println!("rejected (backpressure): {e:?}"),
        }
    }
    let mut ok = 0;
    for rx in rxs {
        if rx.recv().is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("completed {ok}/{n} in {wall:.2}s ({:.1} req/s)", ok as f64 / wall);
    println!("{}", server.metrics.summary());
    server.shutdown();
    Ok(())
}
