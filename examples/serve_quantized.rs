//! Multi-model serving example: pack the AOT artifacts into a `.rmsa`
//! zero-copy artifact, load it twice (each load is a header validation
//! plus an `mmap` alias — the float parse-and-quantize pipeline never
//! runs), and serve both residents behind one HTTP/1.1 front-end. The
//! self-query loop routes on the request's `model` field, probes the
//! 404 path for an unknown model, and prints the per-model Prometheus
//! metrics at the end. Python never runs here.
//!
//! Two residents of the same artifact stand in for a fleet's A/B or
//! canary pair; in production each route would point at its own `.rmsa`
//! (`rmsmp serve --http ADDR --models a.rmsa,b.rmsa`). The page cache
//! backs both mappings with one copy of the packed planes.
//!
//! Run after `make artifacts`:
//!     cargo run --release --example serve_quantized [rate_rps] [n_requests]

use std::fmt::Write as _;
use std::time::{Duration, Instant};

use rmsmp::coordinator::batcher::BatchPolicy;
use rmsmp::coordinator::{HttpConfig, HttpServer, Router, ServerConfig, SimpleClient};
use rmsmp::model::{artifact, ModelWeights};
use rmsmp::runtime::artifacts_dir;
use rmsmp::ParallelConfig;

fn main() -> rmsmp::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let rate: f64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(20.0);
    let n: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(80);

    // 1. pack: fold the legacy parse path's inputs (manifest.json +
    //    float weights.bin) into one self-contained artifact — what
    //    `rmsmp pack` and the Python exporter's write_rmsa both emit.
    let dir = artifacts_dir();
    let manifest_json = std::fs::read_to_string(dir.join("manifest.json"))?;
    let weights = ModelWeights::load(&dir.join("weights.bin"))?;
    let rmsa = dir.join("model.rmsa");
    let t0 = Instant::now();
    artifact::pack_to_file(&manifest_json, &weights, &rmsa)?;
    let pack_ms = t0.elapsed().as_secs_f64() * 1e3;
    let size = std::fs::metadata(&rmsa)?.len();
    println!("packed {} layers -> {} ({} KiB, {pack_ms:.1} ms)",
             weights.layers.len(), rmsa.display(), size / 1024);

    // 2. load twice, serve both residents through one router (one
    //    shared GEMM pool, per-model batchers and metrics)
    let t0 = Instant::now();
    let (m_a, w_a) = artifact::load(&rmsa)?;
    let (m_b, w_b) = artifact::load(&rmsa)?;
    let load_ms = t0.elapsed().as_secs_f64() * 1e3;
    let image_len = m_a.input_shape[1] * m_a.input_shape[2] * m_a.input_shape[3];
    let name_a = m_a.model.clone();
    let name_b = format!("{name_a}-canary");
    println!("loaded 2 residents in {load_ms:.2} ms ({} layers each, ratio {})",
             m_a.layers.len(), m_a.ratio);
    let cfg = ServerConfig {
        workers: 1,
        policy: BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(4),
            queue_cap: 512,
        },
        parallel: ParallelConfig::default(),
    };
    let router = Router::start(vec![
        (name_a.clone(), m_a, w_a, cfg.clone()),
        (name_b.clone(), m_b, w_b, cfg),
    ])?;
    let http = HttpServer::start_router(router, HttpConfig::default())?;
    println!("listening on http://{} — try:", http.addr());
    println!(
        "  curl -s http://{}/v1/infer -d '{{\"model\": \"{name_b}\", \"input\": [0.1, ...]}}'",
        http.addr()
    );
    println!("  curl -s http://{}/metrics", http.addr());

    // 3. self-query like curl would: one keep-alive connection, POSTing
    //    JSON bodies at the requested open-loop rate, alternating the
    //    routed model per request
    let addr = http.addr().to_string();
    let mut body = String::with_capacity(image_len * 10 + 96);
    let mut client = SimpleClient::connect(&addr)?;
    let t0 = Instant::now();
    let mut ok = 0;
    let mut shed = 0;
    for k in 0..n {
        let target = Duration::from_secs_f64(k as f64 / rate);
        if let Some(sleep) = target.checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        let model = if k % 2 == 0 { &name_a } else { &name_b };
        body.clear();
        let _ = write!(body, "{{\"model\": \"{model}\", \"deadline_ms\": 250, \"input\": [");
        for i in 0..image_len {
            if i > 0 {
                body.push(',');
            }
            let _ = write!(body, "{}", ((i + k) % 29) as f32 / 29.0);
        }
        body.push_str("]}");
        let resp = client.request("POST", "/v1/infer", &body)?;
        match resp.status {
            200 => ok += 1,
            504 => shed += 1,
            s => println!("request {k}: HTTP {s} {}", resp.body.trim_end()),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("completed {ok}/{n} (shed {shed}) in {wall:.2}s ({:.1} req/s)", ok as f64 / wall);

    // an unrouted model name answers 404 without touching a batcher
    let resp = client.request(
        "POST",
        "/v1/infer",
        &format!("{{\"model\": \"no-such-model\", \"input\": [{}]}}",
                 "0,".repeat(image_len - 1) + "0"),
    )?;
    println!("unknown model -> HTTP {} {}", resp.status, resp.body.trim_end());

    let metrics = client.request("GET", "/metrics", "")?;
    println!("--- GET /metrics (per-model) ---");
    for line in metrics.body.lines().filter(|l| !l.starts_with('#')) {
        println!("{line}");
    }
    println!("{}", http.summary());
    http.shutdown();
    Ok(())
}
